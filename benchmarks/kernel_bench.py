"""Kernel-path micro-benchmarks + the chunked scoring-engine benchmark.

On this CPU container the Pallas kernels run in interpret mode (not
representative of TPU wall time), so the timed numbers here are the XLA-CPU
oracle paths — used to sanity-track the compute shapes. Kernel↔oracle
numerical agreement is asserted in tests/test_kernels.py; TPU timings come
from the roofline model (§Roofline).

``scoring_bench`` times the full pre-sampling phase of Algorithm 1 two ways —
the dense seed pipeline (two full basis evaluations, one-shot Gram, (n·J, m)
hull score matrix) against the chunked two-pass ``ScoringEngine`` — and
records speedup + peak memory into BENCH_scoring.json at the repo root. It
also compares the pass strategies head to head: one-pass sketched vs
two-pass exact wall clock AND data-pass counts (a counting featurize wrapper
verifies the one-pass path streams each row exactly once).

``dist_scoring_bench`` times the sharded chunked ``DistributedScoringEngine``
against the single-host engine on an 8-fake-device CPU mesh (subprocess: the
device count is fixed at first jax init) with a deliberately ragged n, and
records timings + max-abs score agreement into BENCH_dist_scoring.json.

``--smoke`` shrinks every size so the whole bench path runs in seconds
(exercised by tier-1 tests).
"""
from __future__ import annotations

import argparse
import json
import os
import resource
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core.bernstein import bernstein_design, bernstein_deriv_design
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.gram.ref import gram_ref
from repro.kernels.ssd.ref import ssd_ref

REPO_ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))


def _rss_mb() -> float:
    """Process high-water RSS in MiB (monotone — sample in ascending phases)."""
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def scoring_bench(smoke: bool = False, out_path: str | None = None) -> dict:
    """Chunked ScoringEngine vs the dense seed scoring pipeline.

    Uses the paper's bivariate config (J=2, degree 6) on uniform data — every
    Bernstein basis function is well-supported, so the Gram spectrum is
    f32-resolvable and the two paths must agree to atol 1e-5.
    """
    from repro.core import mctm as M
    from repro.core.bernstein import DataScaler
    from repro.core.hull import epsilon_kernel_indices
    from repro.core.leverage import flatten_features, leverage_scores_gram
    from repro.core.scoring import ScoringEngine

    n = 30_000 if smoke else 250_000
    k_hull = 16 if smoke else 40          # build_coreset's k2 at k=200, α=0.8
    chunk = 8192 if smoke else 32_768
    J, degree = 2, 6
    rng = np.random.default_rng(0)
    Y = rng.random((n, J)).astype(np.float32)
    cfg = M.MCTMConfig(J=J, degree=degree)
    scaler = DataScaler.fit(Y)
    key = jax.random.PRNGKey(0)

    def dense_seed_path():
        """The pre-engine scoring phase: basis evaluated twice, dense hull."""
        A, _ = M.basis_features(cfg, scaler, jnp.asarray(Y))
        u = np.asarray(leverage_scores_gram(flatten_features(A)))
        scores = u + 1.0 / n
        _, Ap = M.basis_features(cfg, scaler, jnp.asarray(Y))
        P = np.asarray(Ap).reshape(n * cfg.J, cfg.d)
        hull = epsilon_kernel_indices(P, k_hull, key)
        return scores, hull

    engine = ScoringEngine(cfg, scaler, chunk_size=chunk)

    def chunked_path():
        res = engine.score(
            jnp.asarray(Y), method="l2-hull", hull_k=k_hull, hull_key=key
        )
        return res.scores, res.hull_rows

    rss0 = _rss_mb()
    # chunked first: ru_maxrss is monotone, so its reading upper-bounds the
    # chunked phase only if taken before the dense phase runs
    scores_c, hull_c = chunked_path()  # warmup/compile
    us_chunked = time_call(chunked_path, repeats=1 if smoke else 3)
    rss_chunked = _rss_mb()
    scores_d, hull_d = dense_seed_path()  # warmup/compile
    us_dense = time_call(dense_seed_path, repeats=1 if smoke else 3)
    rss_dense = _rss_mb()

    max_diff = float(np.abs(scores_c - scores_d).max())
    overlap = len(set(hull_c.tolist()) & set(hull_d.tolist())) / max(len(hull_d), 1)
    d = cfg.d
    m_dirs = max(4 * k_hull, 8) + 2 * d

    # ---- one-pass sketched vs two-pass exact: wall clock AND data-pass
    # counts, measured with a counting featurize wrapper (each entry is one
    # chunk streamed through the fused basis evaluation)
    from repro.core.scoring import _mctm_featurize

    base_feat = _mctm_featurize(cfg, scaler)
    calls: list[int] = []

    def counting_feat(Yc):
        calls.append(int(Yc.shape[0]))
        return base_feat(Yc)

    eng_cnt = ScoringEngine(
        featurize=counting_feat, chunk_size=chunk, rows_per_point=cfg.J
    )
    D = cfg.J * cfg.d
    sketch = 4 * D * D  # constant-factor OSE regime, still ≪ n
    skey = jax.random.PRNGKey(42)

    def two_pass_path():
        return eng_cnt.score(
            jnp.asarray(Y), method="l2-hull", hull_k=k_hull, hull_key=key
        ).scores

    def one_pass_path():
        return eng_cnt.score(
            jnp.asarray(Y),
            method="l2-hull",
            hull_k=k_hull,
            hull_key=key,
            sketch_size=sketch,
            key=skey,
        ).scores

    n_chunks = -(-n // chunk)
    scores_1p = one_pass_path()  # warmup/compile
    calls.clear()
    scores_1p = one_pass_path()
    one_pass_rows, one_pass_calls = sum(calls), len(calls)
    us_one_pass = time_call(one_pass_path, repeats=1 if smoke else 3)
    scores_2p = two_pass_path()
    calls.clear()
    scores_2p = two_pass_path()
    two_pass_rows, two_pass_calls = sum(calls), len(calls)
    us_two_pass = time_call(two_pass_path, repeats=1 if smoke else 3)
    # exact leverage is the reference: the sketch pays a constant-factor
    # relative error for the saved sweep
    rel_err = np.abs(scores_1p - scores_2p) / np.maximum(np.abs(scores_2p), 1e-12)

    # fused one-pass sweep body vs the 3 unfused dispatches it replaced,
    # timed at the exact chunk shapes of this bench (plus the per-kernel
    # analytic roofline rows) — see benchmarks/roofline_table.py
    from benchmarks.roofline_table import kernel_roofline

    roofline = kernel_roofline(
        chunk=chunk, J=J, degree=degree, k_hull=k_hull, sketch=sketch,
        repeats=1 if smoke else 3,
    )

    one_pass_rec = {
        "sketch_size": sketch,
        "fused_vs_unfused": roofline["fused_vs_unfused"],
        "two_pass_s": us_two_pass / 1e6,
        "one_pass_s": us_one_pass / 1e6,
        "speedup": us_two_pass / us_one_pass,
        # data-pass accounting: rows streamed through featurize per score
        "two_pass_featurize_calls": two_pass_calls,
        "one_pass_featurize_calls": one_pass_calls,
        "two_pass_rows_streamed": two_pass_rows,
        "one_pass_rows_streamed": one_pass_rows,
        "n_chunks": n_chunks,
        "median_rel_score_err": float(np.median(rel_err)),
        "max_rel_score_err": float(rel_err.max()),
    }
    assert one_pass_rows == n and one_pass_calls == n_chunks, (
        "one-pass strategy must stream each row exactly once"
    )

    rec = {
        "n": n,
        "J": J,
        "degree": degree,
        "k_hull": k_hull,
        "chunk_size": chunk,
        "smoke": smoke,
        "dense_s": us_dense / 1e6,
        "chunked_s": us_chunked / 1e6,
        "speedup": us_dense / us_chunked,
        "max_abs_score_diff": max_diff,
        "hull_overlap": overlap,
        # analytic peak working sets (bytes) of the scoring phase
        "dense_bytes": 2 * n * J * d * 4 * 2 + n * J * m_dirs * 4,
        "chunked_bytes": 2 * chunk * J * d * 4 + chunk * J * m_dirs * 4,
        # monotone process high-water marks (MiB) per phase, in run order
        "rss_mb": {"start": rss0, "after_chunked": rss_chunked, "after_dense": rss_dense},
        # one-pass sketched vs two-pass exact (pass-strategy comparison)
        "one_pass_vs_two_pass": one_pass_rec,
        # per-kernel analytic bytes/FLOPs/AI + measured oracle wall-clock
        "roofline": roofline,
    }
    emit(
        f"scoring/n{n}_J{J}_d{d}/chunk{chunk}",
        us_chunked,
        f"dense={rec['dense_s']:.2f}s chunked={rec['chunked_s']:.2f}s "
        f"speedup={rec['speedup']:.2f}x maxdiff={max_diff:.1e}",
    )
    emit(
        f"scoring_one_pass/n{n}_J{J}_d{d}/sketch{sketch}",
        us_one_pass,
        f"two_pass={one_pass_rec['two_pass_s']:.2f}s "
        f"one_pass={one_pass_rec['one_pass_s']:.2f}s "
        f"passes={one_pass_calls}v{two_pass_calls} "
        f"med_rel_err={one_pass_rec['median_rel_score_err']:.1e}",
    )
    fu = roofline["fused_vs_unfused"]
    emit(
        f"scoring_fused_sweep/chunk{chunk}_sketch{sketch}",
        fu["fused_us"],
        f"unfused={fu['unfused_us']:.0f}us fused={fu['fused_us']:.0f}us "
        f"speedup={fu['measured_speedup']:.2f}x "
        f"traffic={fu['traffic_ratio']:.2f}x",
    )
    if out_path is None:
        # smoke runs land in results/ so they don't churn the committed
        # full-scale artifact at the repo root
        if smoke:
            from benchmarks.common import bench_dir

            out_path = os.path.join(bench_dir("bench"), "BENCH_scoring_smoke.json")
        else:
            out_path = os.path.join(REPO_ROOT, "BENCH_scoring.json")
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def _dist_scoring_child(smoke: bool, out_path: str) -> None:
    """Body of the dist_scoring bench — runs inside a subprocess whose
    XLA_FLAGS force 8 fake CPU devices (set by ``dist_scoring_bench``)."""
    from repro.core import mctm as M
    from repro.core.bernstein import DataScaler
    from repro.core.distributed_coreset import DistributedScoringEngine
    from repro.core.scoring import ScoringEngine
    from repro.utils.compat import make_mesh

    devices = len(jax.devices())
    mesh = make_mesh((devices,), ("data",))
    # ragged on purpose: n % devices != 0 exercises the padding/masking path
    n = 30_001 if smoke else 250_001
    k_hull = 16 if smoke else 40
    chunk = 2048 if smoke else 8192
    # degree 5: every Gram eigenvalue sits above the f32 noise floor, so the
    # two engines are comparable to ~1e-8 (degree 6's starved edge bases put
    # genuine modes at the rcond cutoff — see the ROADMAP f32 item)
    J, degree = 2, 5
    rng = np.random.default_rng(0)
    Y = rng.random((n, J)).astype(np.float32)
    cfg = M.MCTMConfig(J=J, degree=degree)
    scaler = DataScaler.fit(Y)
    key = jax.random.PRNGKey(0)

    single = ScoringEngine(cfg, scaler, chunk_size=chunk)
    dist = DistributedScoringEngine(cfg, scaler, mesh=mesh, chunk_size=chunk)

    from repro.core.coreset import exact_hull_points

    def single_path():
        res = single.score(
            jnp.asarray(Y), method="l2-hull", hull_k=k_hull, hull_key=key
        )
        return res.scores, exact_hull_points(res, res.scores, k_hull)

    def dist_path():
        res = dist.score(
            jnp.asarray(Y), method="l2-hull", hull_k=k_hull, hull_key=key
        )
        return res.scores, exact_hull_points(res, res.scores, k_hull)

    scores_d, hull_d = dist_path()  # warmup/compile
    us_dist = time_call(dist_path, repeats=1 if smoke else 3)
    scores_s, hull_s = single_path()
    us_single = time_call(single_path, repeats=1 if smoke else 3)

    rec = {
        "n": n,
        "J": J,
        "degree": degree,
        "k_hull": k_hull,
        "chunk_size": chunk,
        "devices": devices,
        "smoke": smoke,
        "single_host_s": us_single / 1e6,
        "dist_s": us_dist / 1e6,
        "speedup": us_single / us_dist,
        "max_abs_score_diff": float(np.abs(scores_s - scores_d).max()),
        # the k_hull hull POINTS the coreset consumes (exact_hull_points) —
        # raw candidate tails can flip on near-tied argmaxes across layouts
        "hull_points_equal": bool(np.array_equal(hull_s, hull_d)),
        # per-chip analytic peak working set of the sharded engine (bytes):
        # one (chunk, J, d) basis block + O((Jd)²) pass-1 state
        "dist_chip_bytes": 2 * chunk * J * cfg.d * 4 + (J * cfg.d) ** 2 * 4,
    }
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)


def dist_scoring_bench(smoke: bool = False, out_path: str | None = None) -> dict:
    """Sharded chunked DistributedScoringEngine vs single-host ScoringEngine.

    Spawns a fresh interpreter with ``--xla_force_host_platform_device_count=8``
    (device count is fixed at first jax init, and the parent may already have
    initialized jax) and reads back the JSON record it writes.
    """
    if out_path is None:
        if smoke:
            from benchmarks.common import bench_dir

            out_path = os.path.join(bench_dir("bench"), "BENCH_dist_scoring_smoke.json")
        else:
            out_path = os.path.join(REPO_ROOT, "BENCH_dist_scoring.json")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [REPO_ROOT, os.path.join(REPO_ROOT, "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else [])
    )
    cmd = [sys.executable, "-m", "benchmarks.kernel_bench", "--dist-child", "--out", out_path]
    if smoke:
        cmd.append("--smoke")
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(f"dist_scoring child failed:\n{proc.stderr[-3000:]}")
    with open(out_path) as f:
        rec = json.load(f)
    emit(
        f"dist_scoring/n{rec['n']}_J{rec['J']}_d{rec['degree'] + 1}/dev{rec['devices']}",
        rec["dist_s"] * 1e6,
        f"single={rec['single_host_s']:.2f}s dist={rec['dist_s']:.2f}s "
        f"speedup={rec['speedup']:.2f}x maxdiff={rec['max_abs_score_diff']:.1e}",
    )
    return rec


def run(smoke: bool = False):
    rng = np.random.default_rng(0)

    # bernstein basis path at coreset-scoring scale
    nb = 20_000 if smoke else 200_000
    t = jnp.asarray(rng.random(nb), jnp.float32)
    f = jax.jit(lambda t: (bernstein_design(t, 6), bernstein_deriv_design(t, 6)))
    f(t)  # compile
    us = time_call(f, t)
    emit(f"kernel/bernstein_ref/n{nb}_d7", us, f"{nb * 14 / (us / 1e6) / 1e9:.2f} Gelem/s")

    # gram at leverage scale
    ng = 10_000 if smoke else 100_000
    X = jnp.asarray(rng.standard_normal((ng, 70)), jnp.float32)
    g = jax.jit(gram_ref)
    g(X)
    us = time_call(g, X)
    emit(f"kernel/gram_ref/{ng}x70", us, f"{2 * ng * 70 * 70 / (us / 1e6) / 1e9:.1f} GFLOP/s")

    # attention at test scale
    S = 128 if smoke else 512
    q = jnp.asarray(rng.standard_normal((8, S, 64)), jnp.bfloat16)
    a = jax.jit(lambda q: attention_ref(q, q, q))
    a(q)
    us = time_call(a, q)
    emit(f"kernel/attention_ref/8x{S}x64", us, "oracle path")

    # ssd at test scale
    BH, T, P, N = (4, 128, 64, 32) if smoke else (16, 512, 64, 32)
    x = jnp.asarray(rng.standard_normal((BH, T, P)), jnp.float32)
    dt = jnp.asarray(rng.random((BH, T, 1)) * 0.5 + 0.01, jnp.float32)
    A = jnp.asarray(-rng.random((BH, 1)) - 0.1, jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((BH, T, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((BH, T, N)), jnp.float32)
    s = jax.jit(ssd_ref)
    s(x, dt, A, Bm, Cm)
    us = time_call(s, x, dt, A, Bm, Cm)
    emit(f"kernel/ssd_ref/{BH}x{T}", us, "oracle sequential scan")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke", action="store_true", help="tiny sizes — seconds, for CI"
    )
    ap.add_argument("--dist-child", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.dist_child:
        _dist_scoring_child(args.smoke, args.out)
        return
    run(smoke=args.smoke)
    scoring_bench(smoke=args.smoke)
    dist_scoring_bench(smoke=args.smoke)


if __name__ == "__main__":
    main()
