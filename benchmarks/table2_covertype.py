"""Paper Table 2: Covertype-like 10-dim data, 5 methods × coreset sizes."""
from __future__ import annotations

import json

import jax
import numpy as np

from benchmarks.common import bench_dir, emit
from repro.core import mctm as M
from repro.core.bernstein import DataScaler
from repro.core.coreset import CORESET_METHODS, evaluate_coreset
from repro.data.covertype import generate_covertype


def run(n: int = 50_000, ks=(50, 200, 500), reps: int = 2, steps: int = 500):
    Y = generate_covertype(n, seed=0)
    cfg = M.MCTMConfig(J=10, degree=6)
    scaler = DataScaler.fit(Y)
    import time as _t

    t0 = _t.perf_counter()
    full = M.fit_mctm(cfg, scaler, Y, steps=steps)
    full_s = _t.perf_counter() - t0
    out = []
    for k in ks:
        for method in CORESET_METHODS:
            evs = [
                evaluate_coreset(
                    cfg, scaler, Y, full, k=k, method=method,
                    key=jax.random.PRNGKey(31 * k + r), steps=steps,
                )
                for r in range(reps)
            ]
            rec = {
                "k": k,
                "method": method,
                "param_l2": float(np.mean([e.param_l2 for e in evs])),
                "lambda_err": float(np.mean([e.lambda_err for e in evs])),
                "lr": float(np.mean([e.likelihood_ratio for e in evs])),
                "fit_s": float(np.mean([e.fit_seconds for e in evs])),
                "full_fit_s": full_s,
            }
            out.append(rec)
            emit(
                f"table2/covertype/{method}/k{k}",
                rec["fit_s"] * 1e6,
                f"LR={rec['lr']:.3f} param_l2={rec['param_l2']:.2f} "
                f"speedup={full_s / max(rec['fit_s'], 1e-9):.1f}x",
            )
    with open(f"{bench_dir('bench')}/table2_covertype.json", "w") as f:
        json.dump(out, f, indent=1)
    return out


def main():
    run()


if __name__ == "__main__":
    main()
