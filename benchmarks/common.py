"""Shared benchmark helpers: timing, CSV emission, result dirs."""
from __future__ import annotations

import os
import time

import jax
import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def bench_dir(name: str) -> str:
    d = os.path.normpath(os.path.join(RESULTS_DIR, name))
    os.makedirs(d, exist_ok=True)
    return d


def time_call(fn, *args, repeats: int = 3, **kw) -> float:
    """Median wall time in µs (blocks on jax arrays)."""
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out) if hasattr(out, "block_until_ready") or isinstance(
            out, (jax.Array, tuple, list, dict)
        ) else None
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
