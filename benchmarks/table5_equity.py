"""Paper Tables 5/6 + Figure 1: equity-return panels (10 and 20 stocks)."""
from __future__ import annotations

import json

import jax
import numpy as np

from benchmarks.common import bench_dir, emit
from repro.core import mctm as M
from repro.core.bernstein import DataScaler
from repro.core.coreset import evaluate_coreset
from repro.data.equity import generate_equity_returns

METHODS = ("l2-hull", "l2-only", "uniform")


def run(n: int = 10_000, stocks=(10, 20), ks=(50, 100, 200, 300), reps: int = 2, steps: int = 500):
    out = []
    for J in stocks:
        Y = generate_equity_returns(n, J, seed=0)
        cfg = M.MCTMConfig(J=J, degree=6)
        scaler = DataScaler.fit(Y)
        import time as _t

        t0 = _t.perf_counter()
        full = M.fit_mctm(cfg, scaler, Y, steps=steps)
        full_s = _t.perf_counter() - t0
        for k in ks:
            for method in METHODS:
                evs = [
                    evaluate_coreset(
                        cfg, scaler, Y, full, k=k, method=method,
                        key=jax.random.PRNGKey(7 * k + r + J), steps=steps,
                    )
                    for r in range(reps)
                ]
                rec = {
                    "stocks": J,
                    "k": k,
                    "method": method,
                    "param_l2": float(np.mean([e.param_l2 for e in evs])),
                    "lambda_err": float(np.mean([e.lambda_err for e in evs])),
                    "lr": float(np.mean([e.likelihood_ratio for e in evs])),
                    "fit_s": float(np.mean([e.fit_seconds for e in evs])),
                    "full_fit_s": full_s,
                }
                out.append(rec)
                emit(
                    f"table5/equity{J}/{method}/k{k}",
                    rec["fit_s"] * 1e6,
                    f"LR={rec['lr']:.3f} param_l2={rec['param_l2']:.2f}",
                )
    with open(f"{bench_dir('bench')}/table5_equity.json", "w") as f:
        json.dump(out, f, indent=1)
    return out


def main():
    run()


if __name__ == "__main__":
    main()
