"""Paper Figure 9 / §3 timing claims: sampling + fit time vs n and method.

Headline: coreset construction + coreset fit ≪ full fit, gap widening with n.
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from benchmarks.common import bench_dir, emit
from repro.core import mctm as M
from repro.core.bernstein import DataScaler
from repro.core.coreset import build_coreset
from repro.data.dgp import generate


def run(sizes=(10_000, 50_000, 200_000), k: int = 100, steps: int = 500):
    out = []
    for n in sizes:
        Y = generate("normal_mixture", n, seed=0)
        cfg = M.MCTMConfig(J=2, degree=6)
        scaler = DataScaler.fit(Y)
        t0 = time.perf_counter()
        full = M.fit_mctm(cfg, scaler, Y, steps=steps)
        full_s = time.perf_counter() - t0
        rec = {"n": n, "full_fit_s": full_s}
        for method in ("l2-hull", "l2-only", "uniform"):
            t0 = time.perf_counter()
            cs = build_coreset(cfg, scaler, Y, k, method, key=jax.random.PRNGKey(0))
            sample_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            M.fit_mctm(
                cfg, scaler, Y[cs.indices],
                weights=np.asarray(cs.weights, np.float32), steps=steps,
            )
            fit_s = time.perf_counter() - t0
            rec[method] = {"sample_s": sample_s, "fit_s": fit_s}
            emit(
                f"fig9/n{n}/{method}",
                (sample_s + fit_s) * 1e6,
                f"full={full_s:.2f}s coreset={sample_s + fit_s:.2f}s "
                f"speedup={full_s / (sample_s + fit_s):.1f}x",
            )
        out.append(rec)
    with open(f"{bench_dir('bench')}/fig9_timing.json", "w") as f:
        json.dump(out, f, indent=1)
    return out


def main():
    run()


if __name__ == "__main__":
    main()
