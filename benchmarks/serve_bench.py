"""Density-serving throughput/latency bench → BENCH_serve.json.

Measures the serving-layer claims (ROADMAP item 1) on a fitted MCTM:

* ``coalesced_vs_unbatched.speedup`` — queries/s through the continuous-
  batching engine at ``max_batch`` vs the same queries served one request
  per dispatch (a ``max_batch=1`` engine — identical code path, bucket 1).
  Gated with an absolute floor: coalescing must stay ≥ 5x at smoke load.
* ``load_sweep`` — open-loop synthetic arrivals at several offered QPS
  levels; p50/p99 request latency and achieved (sustained) QPS per level.
  Arrival times are precomputed (open loop: the client does not wait for
  answers), so queueing shows up in the tail exactly as it would live.
* ``steady_state_recompiles`` — XLA traces observed AFTER the warmup pass
  across all of the above mixed traffic (every bucket, both query kinds,
  one hot swap). Invariant-gated at 0.
* ``hot_swap`` — a background refit (fresh coreset → streaming L-BFGS via
  ``serve.density.refit_and_publish``) published mid-traffic:
  publish→visible stall, dropped queries (gated 0), and mixed-params
  queries — every answer must match its recorded model version's reference
  exactly-one-of-old-or-new (gated 0).

Run: ``PYTHONPATH=src:. python benchmarks/serve_bench.py --smoke``
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

REPO_ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))


def _percentiles(lat_s: list[float]) -> dict:
    lat = np.asarray(lat_s, np.float64) * 1e3
    return {
        "p50_ms": float(np.percentile(lat, 50)),
        "p99_ms": float(np.percentile(lat, 99)),
        "max_ms": float(lat.max()),
    }


def serve_bench(smoke: bool = False, out_path: str | None = None) -> dict:
    import jax.numpy as jnp

    from repro.core import mctm as M
    from repro.core.bernstein import DataScaler
    from repro.core.coreset import build_coreset
    from repro.core.mctm_fit import fit_mctm_streaming
    from repro.data.dgp import generate
    from repro.serve.density import DensityServeEngine, start_background_refit

    n = 20_000 if smoke else 200_000
    k = 400 if smoke else 2000
    steps = 60 if smoke else 200
    degree = 5
    max_batch = 64 if smoke else 256
    n_queries = 2048 if smoke else 16_384
    offered_qps = [500, 2000, 8000] if smoke else [1000, 5000, 20_000, 50_000]

    cfg = M.MCTMConfig(J=2, degree=degree)
    Y = generate("normal_mixture", n, seed=0).astype(np.float32)
    scaler = DataScaler.fit(Y)
    key = jax.random.PRNGKey(0)
    k_build, k_fit, k_refit, k_serve = jax.random.split(key, 4)

    cs = build_coreset(cfg, scaler, Y, k, "l2-hull", key=k_build)
    fit = fit_mctm_streaming(
        cfg, scaler, Y[cs.indices], weights=np.asarray(cs.weights, np.float32),
        key=k_fit, steps=steps, method="lbfgs",
    )
    rng = np.random.default_rng(1)
    qY = Y[rng.integers(0, n, size=n_queries)]

    def fresh_engine(mb):
        e = DensityServeEngine(cfg, fit.params, scaler, max_batch=mb,
                               min_bucket=min(8, mb), sample_key=k_serve)
        e.warmup()
        return e

    # ---- coalesced vs unbatched (same code path, bucket ladder vs bucket 1)
    def closed_loop_qps(mb, m) -> float:
        eng = fresh_engine(mb)
        t0 = time.perf_counter()
        i = 0
        while i < m:
            b = min(mb, m - i)
            eng.submit_log_density(qY[i:i + b])
            eng.submit_sample(b, seeds=list(range(i, i + b)))
            eng.run_until_drained()
            i += b
        return 2 * m / (time.perf_counter() - t0)

    m_un = max(n_queries // 16, 64)  # per-dispatch serving is slow — subsample
    unbatched_qps = closed_loop_qps(1, m_un)
    coalesced_qps = closed_loop_qps(max_batch, n_queries)
    speedup = coalesced_qps / unbatched_qps

    # ---- open-loop load sweep: precomputed arrival times, mixed kinds
    load_sweep = []
    for qps in offered_qps:
        eng = fresh_engine(max_batch)
        m = min(n_queries, max(256, qps))  # ≥1s of traffic per level
        arrivals = np.arange(m) / qps
        reqs = []
        t0 = time.perf_counter()
        i = 0
        while i < m or any(eng.queues.values()):
            now = time.perf_counter() - t0
            while i < m and arrivals[i] <= now:
                if i % 4 == 3:
                    reqs += eng.submit_sample(1, y_obs=qY[i], n_obs=1, seeds=[i])
                else:
                    reqs += eng.submit_log_density(qY[i][None])
                i += 1
            eng.step()
        wall = time.perf_counter() - t0
        load_sweep.append({
            "offered_qps": qps,
            "achieved_qps": m / wall,
            "queries": m,
            **_percentiles([r.latency_s for r in reqs]),
        })

    # ---- hot swap under traffic: background refit, exact version audit
    eng = fresh_engine(max_batch)
    warm = eng.compile_count
    refit = start_background_refit(
        eng, scaler, Y, k, key=k_refit, method="lbfgs", steps=steps)
    reqs = []
    i = 0
    while (refit.is_alive() or eng.version < 1 or i < 512) and i < 10 * n_queries:
        burst = max_batch // 2
        reqs += engine_submit_mixed(eng, qY, i, burst)
        i += burst
        eng.step()
    refit.join()
    eng.run_until_drained()
    recompiles = eng.compile_count - warm
    stall = [e["visible_s"] - e["published_s"]
             for e in eng.swap_events if e["visible_s"] is not None]
    # audit: every log_density answer matches its recorded version exactly-
    # one-of-old-or-new (version 1's params are live in the engine slot)
    refs = {
        0: np.asarray(M.log_density(cfg, fit.params, scaler, jnp.asarray(qY))),
        1: np.asarray(
            M.log_density(cfg, eng._slot.params, scaler, jnp.asarray(qY))
        ),
    }
    mixed = dropped = 0
    for j, r in enumerate(reqs):
        if not r.done:
            dropped += 1
            continue
        if r.kind != "log_density":
            continue
        qi = int(r.uid_qi)
        err_mine = abs(r.result - refs[r.version][qi])
        err_other = min(abs(r.result - refs[v][qi]) for v in refs if v != r.version)
        if err_mine > 1e-5 and err_other <= err_mine:
            mixed += 1
    hot_swap = {
        "dropped": dropped,
        "mixed_params_queries": mixed,
        "versions_served": sorted({r.version for r in reqs if r.done}),
        "publish_to_visible_ms": float(max(stall) * 1e3) if stall else None,
        "queries_in_flight": len(reqs),
    }

    rec = {
        "n": n,
        "k": k,
        "degree": degree,
        "steps": steps,
        "max_batch": max_batch,
        "buckets": list(fresh_engine(max_batch).buckets),
        "smoke": bool(smoke),
        "coalesced_vs_unbatched": {
            "unbatched_qps": unbatched_qps,
            "coalesced_qps": coalesced_qps,
            "speedup": speedup,
        },
        "load_sweep": load_sweep,
        "steady_state_recompiles": recompiles,
        "hot_swap": hot_swap,
        "zero_dropped_or_mixed": bool(dropped == 0 and mixed == 0),
    }
    if out_path is None:
        if smoke:
            from benchmarks.common import bench_dir

            out_path = os.path.join(bench_dir("bench"), "BENCH_serve_smoke.json")
        else:
            out_path = os.path.join(REPO_ROOT, "BENCH_serve.json")
    os.makedirs(os.path.dirname(os.path.abspath(out_path)), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[serve_bench] coalesced {coalesced_qps:.0f} QPS vs unbatched "
          f"{unbatched_qps:.0f} QPS → {speedup:.1f}x  "
          f"recompiles={recompiles}  dropped={dropped} mixed={mixed}",
          flush=True)
    for row in load_sweep:
        print(f"[serve_bench] offered {row['offered_qps']:>6} QPS → achieved "
              f"{row['achieved_qps']:8.0f}  p50 {row['p50_ms']:6.2f}ms  "
              f"p99 {row['p99_ms']:7.2f}ms", flush=True)
    print(f"[serve_bench] wrote {out_path}", flush=True)
    if not rec["zero_dropped_or_mixed"] or recompiles != 0:
        raise SystemExit("[serve_bench] serving contract violated")
    return rec


def engine_submit_mixed(eng, qY, start, burst):
    reqs = []
    for i in range(start, start + burst):
        qi = i % len(qY)
        if i % 4 == 3:
            r = eng.submit_sample(1, y_obs=qY[qi], n_obs=1, seeds=[i])
        else:
            r = eng.submit_log_density(qY[qi][None])
        r[0].uid_qi = qi  # remember which query row, for the version audit
        reqs += r
    return reqs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes — seconds, for CI")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    serve_bench(smoke=args.smoke, out_path=args.out)


if __name__ == "__main__":
    main()
