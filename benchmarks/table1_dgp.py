"""Paper Table 1 (+ Tables 3/4): coreset methods across DGPs.

For each DGP: full-data MCTM fit baseline, then ℓ2-hull / ℓ2-only / uniform
coresets at k ∈ {30, 100}, metrics = (param ℓ2, λ error, likelihood ratio),
mean ± std over repetitions — the paper's exact workflow (§E.1.3).
"""
from __future__ import annotations

import json

import jax
import numpy as np

from benchmarks.common import bench_dir, emit, time_call
from repro.core import mctm as M
from repro.core.bernstein import DataScaler
from repro.core.coreset import evaluate_coreset
from repro.data.dgp import generate

# paper Table 1 rows (5 representative scenarios)
TABLE1_DGPS = (
    "bivariate_normal",
    "nonlinear_correlation",
    "normal_mixture",
    "geometric_mixed",
    "heteroscedastic",
)
METHODS = ("l2-hull", "l2-only", "uniform")


def run(
    dgps=TABLE1_DGPS,
    ks=(30,),
    n: int = 10_000,
    reps: int = 3,
    steps: int = 700,
    tag: str = "table1",
) -> list[dict]:
    if dgps is None:  # full 14-DGP sweep (paper Tables 3/4)
        from repro.data.dgp import DGP_NAMES

        dgps = DGP_NAMES
    out = []
    for dgp in dgps:
        Y = generate(dgp, n, seed=0)
        cfg = M.MCTMConfig(J=2, degree=6)
        scaler = DataScaler.fit(Y)
        import time as _t

        t0 = _t.perf_counter()
        full = M.fit_mctm(cfg, scaler, Y, steps=steps)
        full_s = _t.perf_counter() - t0
        for k in ks:
            for method in METHODS:
                evs = [
                    evaluate_coreset(
                        cfg, scaler, Y, full, k=k, method=method,
                        key=jax.random.PRNGKey(1000 * k + r), steps=steps,
                    )
                    for r in range(reps)
                ]
                rec = {
                    "dgp": dgp,
                    "method": method,
                    "k": k,
                    "param_l2": float(np.mean([e.param_l2 for e in evs])),
                    "param_l2_std": float(np.std([e.param_l2 for e in evs])),
                    "lambda_err": float(np.mean([e.lambda_err for e in evs])),
                    "lr": float(np.mean([e.likelihood_ratio for e in evs])),
                    "lr_std": float(np.std([e.likelihood_ratio for e in evs])),
                    "fit_s": float(np.mean([e.fit_seconds for e in evs])),
                    "sample_s": float(np.mean([e.sample_seconds for e in evs])),
                    "full_fit_s": full_s,
                }
                out.append(rec)
                emit(
                    f"{tag}/{dgp}/{method}/k{k}",
                    rec["fit_s"] * 1e6,
                    f"LR={rec['lr']:.3f} param_l2={rec['param_l2']:.2f} "
                    f"lam={rec['lambda_err']:.3f} speedup={full_s / max(rec['fit_s'], 1e-9):.1f}x",
                )
    with open(f"{bench_dir('bench')}/{tag}.json", "w") as f:
        json.dump(out, f, indent=1)
    return out


def main():
    run()


if __name__ == "__main__":
    main()
