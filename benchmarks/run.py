"""Benchmark aggregator — one function per paper table.

Prints ``name,us_per_call,derived`` CSV lines. Sub-benchmarks:
  table1   — Table 1 (+3/4 methodology): DGP coreset comparison
  table2   — Table 2: Covertype-like 10-d data, 5 methods
  table5   — Tables 5/6: equity panels (10/20 stocks)
  fig9     — timing vs n (speedup headline)
  kernels  — kernel-path micro-benchmarks
  scoring  — chunked ScoringEngine vs dense seed pipeline → BENCH_scoring.json
  roofline — §Roofline aggregation of the dry-run artifacts

``python -m benchmarks.run [--quick] [--only table1,roofline]``
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="reduced sizes/reps")
    ap.add_argument("--only", default=None, help="comma list of benches")
    args = ap.parse_args()

    from benchmarks import fig9_timing, kernel_bench, roofline_table, table1_dgp
    from benchmarks import table2_covertype, table5_equity

    q = args.quick
    benches = {
        "table1": lambda: table1_dgp.run(
            reps=2 if q else 3, n=4000 if q else 10_000, steps=500 if q else 700
        ),
        # full 14-DGP sweep (paper Tables 3/4) — run explicitly via --only
        "table34": lambda: table1_dgp.run(
            dgps=None, reps=2 if q else 3, n=4000 if q else 10_000,
            steps=500 if q else 700, tag="table34",
        ),
        "table2": lambda: table2_covertype.run(
            n=10_000 if q else 50_000, ks=(50, 200) if q else (50, 200, 500),
            reps=1 if q else 2, steps=400 if q else 500,
        ),
        "table5": lambda: table5_equity.run(
            n=4000 if q else 10_000, stocks=(10,) if q else (10, 20),
            ks=(50, 200) if q else (50, 100, 200, 300),
            reps=1 if q else 2, steps=400 if q else 500,
        ),
        "fig9": lambda: fig9_timing.run(
            sizes=(10_000, 50_000) if q else (10_000, 50_000, 200_000)
        ),
        "kernels": lambda: kernel_bench.run(smoke=q),
        "scoring": lambda: kernel_bench.scoring_bench(smoke=q),
        "roofline": roofline_table.main,
    }
    selected = args.only.split(",") if args.only else list(benches)
    print("name,us_per_call,derived")
    t0 = time.time()
    failures = []
    for name in selected:
        try:
            benches[name]()
        except Exception:
            failures.append(name)
            traceback.print_exc()
    print(f"# total bench time: {time.time() - t0:.1f}s, failures: {failures or 'none'}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
