"""§Roofline aggregator: results/dryrun/*.json → markdown + CSV tables."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import RESULTS_DIR, bench_dir, emit

COLS = (
    "arch", "shape", "mesh", "dominant", "compute_s", "memory_s",
    "collective_s", "useful_ratio",
)


def load(results_dir=None) -> list[dict]:
    d = results_dir or os.path.join(RESULTS_DIR, "dryrun")
    recs = []
    for path in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def roofline_fraction(r: dict) -> float:
    t = max(r["compute_s"], r["memory_s"], r["collective_s"])
    return r["compute_s"] / t if t > 0 else 0.0


def markdown_table(recs: list[dict], mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "roofline frac | useful | peak GB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("skipped"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | SKIP ({r['reason'][:40]}…) | — | — | — |"
            )
            continue
        if "error" in r or r.get("mesh") != mesh:
            continue
        mem = r.get("memory_analysis", {})
        peak = (mem.get("temp_size_in_bytes", 0) + mem.get("argument_size_in_bytes", 0)) / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | {r['memory_s']:.4f} "
            f"| {r['collective_s']:.4f} | {r['dominant']} | {roofline_fraction(r):.2f} "
            f"| {r.get('useful_ratio', float('nan')):.2f} | {peak:.1f} |"
        )
    return "\n".join(lines)


def main():
    recs = load()
    ok = [r for r in recs if not r.get("skipped") and "error" not in r]
    skip = [r for r in recs if r.get("skipped")]
    err = [r for r in recs if "error" in r]
    d = bench_dir("bench")
    for mesh in ("16x16", "2x16x16"):
        md = markdown_table([r for r in recs if r.get("mesh") == mesh or r.get("skipped")], mesh)
        with open(os.path.join(d, f"roofline_{mesh}.md"), "w") as f:
            f.write(md + "\n")
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"], r.get("variant", ""))):
        variant = r.get("variant", "baseline")
        tag = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        if variant != "baseline":
            tag += f"/{variant}"
        emit(
            tag,
            max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
            f"dom={r['dominant']} frac={roofline_fraction(r):.2f} "
            f"useful={r.get('useful_ratio', float('nan')):.2f}",
        )
    print(f"# roofline cells: ok={len(ok)} skipped={len(skip)} errors={len(err)}")


if __name__ == "__main__":
    main()
