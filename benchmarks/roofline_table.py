"""Kernel roofline table: the repo's REAL scoring kernels, not LM dry-runs.

The seed-era aggregator consumed ``results/dryrun/*.json`` LM cells; the
scoring engines' hot loops are the kernels below, so the roofline now derives
per-chunk analytic HBM traffic / FLOPs / arithmetic intensity from the kernel
shapes (TPU v5e peaks from ``repro.launch.roofline``) and pairs them with the
measured jnp-oracle wall-clock — the XLA path actually timed on this CPU
container (kernel↔oracle numerical agreement is asserted in
``tests/test_kernels.py`` / ``tests/test_sweep_kernel.py``; compiled-Pallas
TPU timings belong to the on-TPU validation item in ROADMAP).

Kernels covered, at the scoring bench's chunk shapes:

* ``bernstein``  — fused basis+derivative featurize of one chunk
* ``gram``       — the (chunk, D) → (D, D) Gram accumulation step
* ``extremes``   — directional hull extremes of the derivative rows
* ``fused_sweep``— the one-pass sweep body (CountSketch + z + extremes in
  one residency), with the traffic of the three unfused dispatches it
  replaces alongside — the ``traffic_ratio`` column is the HBM round-trips
  the fusion removes.

``kernel_roofline(...)`` returns the record ``kernel_bench.scoring_bench``
embeds in BENCH_scoring.json; ``main()`` renders the markdown table + CSV
lines for ``benchmarks/run.py``.
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_dir, emit, time_call
from repro.launch.roofline import HBM_BW, PEAK_FLOPS

F32 = 4  # bytes per element, every kernel here streams f32


def _derived(name: str, flops: float, bytes_: float, wall_us: float) -> dict:
    """One roofline row: analytic intensity + measured achieved rates and the
    TPU-v5e projection (which term binds at peak)."""
    s = wall_us / 1e6
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_ / HBM_BW
    return {
        "kernel": name,
        "flops": flops,
        "bytes": bytes_,
        "ai": flops / bytes_,
        "wall_us": wall_us,
        "achieved_gflops": flops / s / 1e9,
        "achieved_gbps": bytes_ / s / 1e9,
        "tpu_v5e": {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "dominant": "compute" if compute_s >= memory_s else "memory",
        },
    }


def kernel_roofline(
    *,
    chunk: int = 32_768,
    J: int = 2,
    degree: int = 6,
    k_hull: int = 40,
    sketch: int | None = None,
    repeats: int = 3,
) -> dict:
    """Analytic + measured roofline record at the scoring bench's shapes."""
    from repro.core.bernstein import bernstein_design, bernstein_deriv_design
    from repro.core.scoring import sketch_plan
    from repro.kernels.extremes.ref import directional_extremes_ref
    from repro.kernels.gram.ref import gram_ref
    from repro.kernels.sweep.ops import fused_sweep_update

    c = chunk
    d = degree + 1
    D = J * d
    r = J
    m = max(4 * k_hull, 8) + 2 * d  # build_coreset's direction-net size
    sk = sketch if sketch is not None else 4 * D * D

    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.standard_normal((c, D)), jnp.float32)
    P = jnp.asarray(rng.standard_normal((c * r, d)), jnp.float32)
    dirs = jnp.asarray(rng.standard_normal((m, d)), jnp.float32)
    sw = jnp.asarray(rng.random(c) + 0.5, jnp.float32)
    t = jnp.asarray(rng.random(c * J), jnp.float32)
    rows, signs = sketch_plan(jax.random.PRNGKey(0), c, sk)
    SX = jnp.zeros((sk, D), jnp.float32)

    kernels = {}

    # bernstein featurize: (c·J,) knots → basis (c·J, d) + derivative (c·J, d).
    # FLOPs ≈ the degree-recursion cost, ~3·d² fused mul-adds per point-dim
    # (basis power/binomial products + the derivative difference) — analytic
    # approximation, the traffic numbers are exact.
    feat = jax.jit(
        lambda t: (bernstein_design(t, degree), bernstein_deriv_design(t, degree))
    )
    feat(t)
    kernels["bernstein"] = _derived(
        "bernstein",
        flops=c * J * 3 * d * d,
        bytes_=F32 * (c * J + 2 * c * J * d),
        wall_us=time_call(feat, t, repeats=repeats),
    )

    # gram step: XᵀX over one chunk
    gram = jax.jit(gram_ref)
    gram(X)
    kernels["gram"] = _derived(
        "gram",
        flops=2 * c * D * D,
        bytes_=F32 * (c * D + D * D),
        wall_us=time_call(gram, X, repeats=repeats),
    )

    # directional extremes: dirs @ Pᵀ + the 4-way value/index reduction
    ext = jax.jit(directional_extremes_ref)
    ext(P, dirs)
    ext_flops = 2 * m * c * r * d + 4 * m * c * r
    ext_bytes = F32 * (c * r * d + m * d + 4 * m)
    kernels["extremes"] = _derived(
        "extremes",
        flops=ext_flops,
        bytes_=ext_bytes,
        wall_us=time_call(ext, P, dirs, repeats=repeats),
    )

    # fused one-pass sweep: CountSketch (one-hot matmul realization) + z
    # emission + extremes in ONE residency of the streamed rows
    fused = jax.jit(
        lambda SX, X, P, sw, rows, signs, dirs: fused_sweep_update(
            SX, X, P, sw, rows, signs, dirs=dirs, backend="jnp"
        )
    )
    fused(SX, X, P, sw, rows, signs, dirs)
    fused_flops = 2 * sk * c * D + c * D + ext_flops  # sketch + z scale + hull
    fused_bytes = F32 * (
        c * D + c * r * d + m * d + c  # streamed rows + dirs + √w read once
        + sk * D + c * D + 4 * m       # sketch delta + z + extremes out
    )
    kernels["fused_sweep"] = _derived(
        "fused_sweep",
        flops=fused_flops,
        bytes_=fused_bytes,
        wall_us=time_call(fused, SX, X, P, sw, rows, signs, dirs, repeats=repeats),
    )

    # the three dispatches the fusion replaces: scatter re-reads X, the z
    # emission re-reads X, the extremes re-read P — each its own round trip
    def unfused(SX, X, P, sw, rows, signs, dirs):
        Xw = X * sw[:, None]
        SX = SX.at[rows].add(signs[:, None] * Xw)
        z = X * sw[:, None]
        return SX, z, directional_extremes_ref(P, dirs)

    unf = jax.jit(unfused)
    unf(SX, X, P, sw, rows, signs, dirs)
    unfused_us = time_call(unf, SX, X, P, sw, rows, signs, dirs, repeats=repeats)
    unfused_bytes = F32 * (
        2 * (c * D + c) + c * r * d + m * d  # X and √w read twice, P once
        + sk * D + c * D + 4 * m
    )

    return {
        "host_backend": jax.default_backend(),
        "shapes": {
            "chunk": c, "J": J, "degree": degree, "d": d, "D": D, "r": r,
            "m_dirs": m, "sketch": sk,
        },
        "kernels": kernels,
        "fused_vs_unfused": {
            "fused_us": kernels["fused_sweep"]["wall_us"],
            "unfused_us": unfused_us,
            "measured_speedup": unfused_us / kernels["fused_sweep"]["wall_us"],
            "fused_bytes": fused_bytes,
            "unfused_bytes": unfused_bytes,
            "traffic_ratio": unfused_bytes / fused_bytes,
        },
    }


def markdown_table(rec: dict) -> str:
    s = rec["shapes"]
    lines = [
        f"Kernel roofline @ chunk={s['chunk']} J={s['J']} degree={s['degree']} "
        f"(D={s['D']}, m={s['m_dirs']}, sketch={s['sketch']}) — "
        f"host={rec['host_backend']}, TPU projection at v5e peaks",
        "",
        "| kernel | FLOPs | bytes | AI (F/B) | wall (µs) | GFLOP/s | GB/s | v5e-bound |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for k in rec["kernels"].values():
        lines.append(
            f"| {k['kernel']} | {k['flops']:.3g} | {k['bytes']:.3g} "
            f"| {k['ai']:.2f} | {k['wall_us']:.0f} | {k['achieved_gflops']:.2f} "
            f"| {k['achieved_gbps']:.2f} | {k['tpu_v5e']['dominant']} |"
        )
    fu = rec["fused_vs_unfused"]
    lines += [
        "",
        f"Fused sweep vs the 3 unfused dispatches it replaces: "
        f"{fu['measured_speedup']:.2f}× measured "
        f"({fu['unfused_us']:.0f} → {fu['fused_us']:.0f} µs), "
        f"{fu['traffic_ratio']:.2f}× analytic HBM traffic.",
    ]
    return "\n".join(lines)


def main(smoke: bool = False):
    rec = kernel_roofline(
        chunk=8192 if smoke else 32_768,
        k_hull=16 if smoke else 40,
        repeats=1 if smoke else 3,
    )
    d = bench_dir("bench")
    with open(os.path.join(d, "roofline_kernels.json"), "w") as f:
        json.dump(rec, f, indent=1)
    with open(os.path.join(d, "roofline_kernels.md"), "w") as f:
        f.write(markdown_table(rec) + "\n")
    for k in rec["kernels"].values():
        emit(
            f"roofline/{k['kernel']}/chunk{rec['shapes']['chunk']}",
            k["wall_us"],
            f"ai={k['ai']:.2f} gflops={k['achieved_gflops']:.2f} "
            f"gbps={k['achieved_gbps']:.2f} v5e={k['tpu_v5e']['dominant']}",
        )
    fu = rec["fused_vs_unfused"]
    emit(
        f"roofline/fused_vs_unfused/chunk{rec['shapes']['chunk']}",
        fu["fused_us"],
        f"speedup={fu['measured_speedup']:.2f}x traffic={fu['traffic_ratio']:.2f}x",
    )
    print(f"# roofline kernels: {len(rec['kernels'])} rows → {d}/roofline_kernels.md")
    return rec


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    main(smoke=ap.parse_args().smoke)
