"""Fault-tolerance overhead + recovery bench → BENCH_ft.json.

Measures what the ft layer (PR: fault-tolerant coreset pipeline) costs when
nothing fails and what it recovers when something does:

* ``ckpt_overhead_ratio`` — chunked scoring sweep with segment checkpoints
  enabled vs plain (same engine, same chunks). Gated with an exact ceiling:
  sweep checkpointing must stay a small multiple of the plain sweep.
* ``resume_bit_identical`` — a sweep killed mid-scan (injected failure) and
  resumed from its segment checkpoint must reproduce the uninterrupted
  scores bit-for-bit (the core resumable-sweep guarantee).
* ``recovery_overhead_ratio`` — a fit killed mid-run and supervised back to
  completion (rollback to the latest atomic checkpoint + replay) vs the
  clean fit; ``recovered`` asserts the final loss matches the clean run
  exactly (full-batch adam replay is deterministic).

Run: ``PYTHONPATH=src:. python benchmarks/ft_bench.py --smoke``
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import jax
import numpy as np

REPO_ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))


def ft_bench(smoke: bool = False, out_path: str | None = None) -> dict:
    import jax.numpy as jnp

    from repro.checkpoint import CheckpointManager
    from repro.core import mctm as M
    from repro.core.bernstein import DataScaler
    from repro.core.mctm_fit import MCTMDensityModel, fit_density_model
    from repro.core.scoring import ScoringEngine
    from repro.ft.config import ft_overrides, get_ft_config
    from repro.ft.failure import FailureSimulator, InjectedFailure
    from repro.optim import adamw

    n = 12_288 if smoke else 120_000
    chunk = 2048
    n_fit = 4096 if smoke else 16_384
    steps = 60 if smoke else 200
    ckpt_every = 15 if smoke else 50

    rng = np.random.default_rng(0)
    Y = rng.random((n, 2)).astype(np.float32)
    cfg = M.MCTMConfig(J=2, degree=5)
    scaler = DataScaler.fit(Y)
    hull_key = jax.random.PRNGKey(7)
    engine = ScoringEngine(cfg, scaler, chunk_size=chunk)

    def sweep(sweep_ckpt=None, resume=False):
        return engine.score(
            jnp.asarray(Y), method="l2-hull", hull_k=16, hull_key=hull_key,
            sweep_ckpt=sweep_ckpt, resume=resume,
        )

    # ---- checkpointed vs plain sweep (warm both paths first: jit is shared,
    # but the ckpt path adds host save I/O — that's the cost under test)
    r_plain = sweep()
    t0 = time.perf_counter()
    r_plain = sweep()
    t_plain = time.perf_counter() - t0
    with tempfile.TemporaryDirectory() as d:
        with ft_overrides(sweep_ckpt_every_chunks=2):
            t0 = time.perf_counter()
            r_ckpt = sweep(sweep_ckpt=d)
            t_ckpt = time.perf_counter() - t0
    ckpt_overhead = t_ckpt / max(t_plain, 1e-9)
    assert np.array_equal(np.asarray(r_plain.scores), np.asarray(r_ckpt.scores))

    # ---- kill mid-sweep, resume, compare bit-for-bit
    ft = get_ft_config()
    with tempfile.TemporaryDirectory() as d:
        with ft_overrides(sweep_ckpt_every_chunks=2):
            ft.simulator = FailureSimulator().inject("scoring", 4)
            try:
                interrupts = 0
                while True:
                    try:
                        r_res = sweep(sweep_ckpt=d, resume=True)
                        break
                    except InjectedFailure:
                        interrupts += 1
            finally:
                ft.simulator = None
    resume_bit_identical = bool(
        interrupts >= 1
        and np.array_equal(np.asarray(r_ckpt.scores), np.asarray(r_res.scores))
        and np.array_equal(np.asarray(r_ckpt.leverage), np.asarray(r_res.leverage))
        and np.array_equal(r_ckpt.hull_rows, r_res.hull_rows)
    )

    # ---- supervised fit recovery: injected crash + rollback/replay vs clean
    Yf = rng.normal(size=(n_fit, 2)).astype(np.float32)
    model = MCTMDensityModel(cfg, DataScaler.fit(Yf))
    p0 = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"Y": Yf, "weights": np.ones(n_fit, np.float32)}

    def fit(inject: bool):
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=2)
            if inject:
                ft.simulator = FailureSimulator().inject("fit", steps // 2)
            try:
                t0 = time.perf_counter()
                _, losses, _ = fit_density_model(
                    model, p0, batch, optimizer=adamw(5e-2), steps=steps,
                    checkpoint=mgr, ckpt_every=ckpt_every,
                )
                return time.perf_counter() - t0, losses
            finally:
                ft.simulator = None

    fit(False)  # warm the jit cache out of both timed paths
    t_clean, l_clean = fit(False)
    t_rec, l_rec = fit(True)
    recovery_overhead = t_rec / max(t_clean, 1e-9)
    recovered = bool(
        len(l_rec) and len(l_clean) and l_rec[-1] == l_clean[-1]
    )

    rec = {
        "smoke": bool(smoke),
        "n_score": n,
        "chunk": chunk,
        "score_chunks": int(r_plain.n_chunks),
        "n_fit": n_fit,
        "fit_steps": steps,
        "ckpt_every": ckpt_every,
        "sweep_ckpt_every_chunks": 2,
        "t_sweep_plain_s": t_plain,
        "t_sweep_ckpt_s": t_ckpt,
        "ckpt_overhead_ratio": ckpt_overhead,
        "scoring_interrupts": interrupts,
        "resume_bit_identical": resume_bit_identical,
        "t_fit_clean_s": t_clean,
        "t_fit_recovered_s": t_rec,
        "recovery_overhead_ratio": recovery_overhead,
        "recovered": recovered,
        "final_loss": float(l_clean[-1]),
    }
    if out_path is None:
        if smoke:
            from benchmarks.common import bench_dir

            out_path = os.path.join(bench_dir("bench"), "BENCH_ft_smoke.json")
        else:
            out_path = os.path.join(REPO_ROOT, "BENCH_ft.json")
    os.makedirs(os.path.dirname(os.path.abspath(out_path)), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[ft_bench] ckpt_overhead {ckpt_overhead:.2f}x  "
          f"resume_bit_identical {resume_bit_identical}  "
          f"recovery_overhead {recovery_overhead:.2f}x  "
          f"recovered {recovered}", flush=True)
    print(f"[ft_bench] wrote {out_path}", flush=True)
    if not (resume_bit_identical and recovered):
        raise SystemExit("[ft_bench] recovery contract violated")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes — seconds, for CI")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    ft_bench(smoke=args.smoke, out_path=args.out)


if __name__ == "__main__":
    main()
