"""Streaming-maintenance bench → BENCH_stream.json.

Measures what the streaming layer (ROADMAP item 2) claims and gates it:

* ``maintain_vs_rebuild.speedup`` — the maintained coreset's median
  per-window push vs rebuilding a batch coreset over the full seen prefix
  at the final window (the cost the maintainer amortizes away). Floor-gated
  ≥ 1.0: if maintenance is not strictly cheaper than rebuilding, the
  streaming layer has no reason to exist.
* ``policy_checks`` — sliding-window eviction drops expired buckets
  exactly; decayed weights match the closed-form geometric sum
  n·(1−γᵀ)/(1−γ); ``result()`` is idempotent.
* ``resume_bit_identical`` — a stream killed mid-window (injected failure)
  and resumed from its window checkpoint must reproduce the uninterrupted
  final coreset bit-for-bit.
* ``drift`` — the compact in-process drill: injected shift detected within
  the latency budget, background refit published, post-refit measured ε̂
  back inside the band, zero dropped/mixed probe queries.

Run: ``PYTHONPATH=src:. python benchmarks/stream_bench.py --smoke``
The script itself exits 1 on any streaming-contract violation; CI
additionally diffs the record against ``benchmarks/baselines/`` via
``scripts/bench_gate.py``.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import jax
import numpy as np

REPO_ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))


def stream_bench(smoke: bool = False, out_path: str | None = None) -> dict:
    from repro.core import mctm as M
    from repro.core.bernstein import DataScaler
    from repro.core.coreset import build_coreset
    from repro.core.mctm_fit import fit_mctm_streaming
    from repro.core.streaming import DriftDetector, StreamingCoresetMaintainer
    from repro.ft.config import get_ft_config
    from repro.ft.failure import FailureSimulator, InjectedFailure
    from repro.serve.density import DensityServeEngine

    if smoke:
        window, n_windows = 512, 12
        k, sketch, degree, fit_steps = 96, 32, 4, 40
    else:
        window, n_windows = 4096, 24
        k, sketch, degree, fit_steps = 256, 64, 6, 60
    n = window * n_windows
    eps = 0.1

    rng = np.random.default_rng(0)
    base = rng.normal(size=(n, 2)).astype(np.float32)
    cfg = M.MCTMConfig(J=2, degree=degree)
    scaler = DataScaler.fit(base)
    key = jax.random.PRNGKey(0)
    windows = [base[i * window : (i + 1) * window] for i in range(n_windows)]

    # ---- maintain vs rebuild: per-window push cost vs full-prefix rebuild
    kw = dict(policy="insertion", sketch_size=sketch)
    m = StreamingCoresetMaintainer(cfg, scaler, k, key, **kw)
    m.push(windows[0])  # warm the jit caches out of the timed pushes
    push_times = []
    for w in windows[1:]:
        t0 = time.perf_counter()
        m.push(w)
        push_times.append(time.perf_counter() - t0)
    t_push = float(np.median(push_times))
    t0 = time.perf_counter()
    build_coreset(cfg, scaler, base, k, "l2-hull",
                  key=jax.random.PRNGKey(3), sketch_size=sketch)
    t_rebuild = time.perf_counter() - t0
    speedup = t_rebuild / max(t_push, 1e-9)

    # ---- policy checks
    W = 3
    ms = StreamingCoresetMaintainer(
        cfg, scaler, k, key, policy="sliding", window=W, sketch_size=sketch
    )
    for w in windows[:6]:
        ms.push(w)
    sliding_ok = ms.live_births() == list(range(6 - W, 6))
    r1, r2 = ms.result(), ms.result()
    idempotent = bool(
        np.array_equal(r1.Y, r2.Y) and np.array_equal(r1.weights, r2.weights)
    )
    gamma, T = 0.7, 6
    md = StreamingCoresetMaintainer(
        cfg, scaler, k, key, policy="decayed", decay=gamma
    )
    for w in windows[:T]:
        md.push(w)
    expect = window * (1 - gamma**T) / (1 - gamma)
    decay_rel_err = abs(md.total_weight() - expect) / expect
    decayed_ok = bool(decay_rel_err < 1e-4)

    # ---- kill mid-stream, resume from the window checkpoint, compare bits
    ft = get_ft_config()
    n_resume = 6
    ref = StreamingCoresetMaintainer(cfg, scaler, k, key, **kw)
    for w in windows[:n_resume]:
        ref.push(w)
    rr = ref.result()
    with tempfile.TemporaryDirectory() as d:
        ft.simulator = FailureSimulator().inject("streaming", 4)
        try:
            interrupts = 0
            mi = StreamingCoresetMaintainer(cfg, scaler, k, key, ckpt_dir=d, **kw)
            done = 0
            while done < n_resume:
                try:
                    mi.push(windows[done])
                    done = mi.windows_done
                except InjectedFailure:
                    interrupts += 1
                    mi = StreamingCoresetMaintainer(
                        cfg, scaler, k, key, ckpt_dir=d, **kw
                    )
                    done = mi.resume()
        finally:
            ft.simulator = None
        ri = mi.result()
    resume_bit_identical = bool(
        interrupts >= 1
        and np.array_equal(np.asarray(rr.Y), np.asarray(ri.Y))
        and np.array_equal(np.asarray(rr.weights), np.asarray(ri.weights))
    )

    # ---- compact drift drill: shift → detect → refit → band recovery
    drift_rows = (base[: 6 * window] * 1.6 + 2.0 * base.std(axis=0)).astype(
        np.float32
    )
    dscaler = DataScaler.fit(np.concatenate([base, drift_rows]))
    fit0 = fit_mctm_streaming(
        cfg, dscaler, base[: 2 * window], key=jax.random.PRNGKey(1),
        steps=fit_steps, method="lbfgs",
    )
    engine = DensityServeEngine(cfg, fit0.params, dscaler, max_batch=32)
    engine.warmup(kinds=("log_density",))
    det = DriftDetector(eps=eps, alpha=0.5, min_windows=2)
    mdrill = StreamingCoresetMaintainer(
        cfg, dscaler, k, jax.random.PRNGKey(2), policy="sliding", window=4,
        sketch_size=sketch, serve_engine=engine, detector=det,
        refit_kwargs=dict(steps=fit_steps, method="lbfgs"),
    )
    mixed = dropped = 0
    pre, post = 4, 6
    for i in range(pre + post):
        rows = (
            windows[2 + i][: window]
            if i < pre
            else drift_rows[(i - pre) * window : (i - pre + 1) * window]
        )
        mdrill.push(rows)
        if mdrill.drift_log[-1]["triggered"]:
            while engine.refit_in_flight:
                time.sleep(0.05)
        reqs = engine.submit_log_density(rows[:8])
        engine.run_until_drained()
        dropped += sum(0 if r.done else 1 for r in reqs)
        if len({r.version for r in reqs if r.done}) > 1:
            mixed += 1
    dlog = mdrill.drift_log
    fired = [e for e in dlog[pre:] if e["fired"]]
    detected = bool(fired)
    latency = (fired[0]["window"] - pre + 1) if fired else n_windows
    post_log = [e for e in dlog if e["version"] >= 1]
    post_eps = float(post_log[-1]["eps_hat"]) if post_log else float("inf")
    post_in_band = bool(post_log and post_eps <= eps)

    rec = {
        "smoke": bool(smoke),
        "n": n,
        "window": window,
        "n_windows": n_windows,
        "k": k,
        "degree": degree,
        "sketch_size": sketch,
        "maintain_vs_rebuild": {
            "t_push_median_s": t_push,
            "t_rebuild_s": t_rebuild,
            "speedup": speedup,
        },
        "policy_checks": {
            "sliding_evicts_expired": bool(sliding_ok),
            "decayed_weight_matches_closed_form": decayed_ok,
            "decayed_weight_rel_err": float(decay_rel_err),
            "result_idempotent": idempotent,
        },
        "stream_interrupts": interrupts,
        "resume_bit_identical": resume_bit_identical,
        "drift": {
            "eps": eps,
            "detected": detected,
            "detection_latency_windows": int(latency),
            "triggers": int(mdrill.triggered),
            "post_refit_eps_hat": post_eps,
            "post_refit_in_band": post_in_band,
            "mixed_version_batches": int(mixed),
            "dropped_queries": int(dropped),
        },
    }
    if out_path is None:
        if smoke:
            from benchmarks.common import bench_dir

            out_path = os.path.join(bench_dir("bench"), "BENCH_stream_smoke.json")
        else:
            out_path = os.path.join(REPO_ROOT, "BENCH_stream.json")
    os.makedirs(os.path.dirname(os.path.abspath(out_path)), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[stream_bench] maintain_vs_rebuild {speedup:.1f}x  "
          f"sliding_ok {sliding_ok}  decayed_ok {decayed_ok}  "
          f"resume_bit_identical {resume_bit_identical}", flush=True)
    print(f"[stream_bench] drift: detected {detected} "
          f"latency {latency}w  post_eps_hat {post_eps:.4f} "
          f"in_band {post_in_band}  mixed {mixed} dropped {dropped}", flush=True)
    print(f"[stream_bench] wrote {out_path}", flush=True)
    if not (sliding_ok and decayed_ok and idempotent and resume_bit_identical
            and detected and post_in_band and mixed == 0 and dropped == 0
            and speedup >= 1.0):
        raise SystemExit("[stream_bench] streaming contract violated")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes — seconds, for CI")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    stream_bench(smoke=args.smoke, out_path=args.out)


if __name__ == "__main__":
    main()
