"""Streaming scenario: maintain an MCTM coreset over an insertion stream with
Merge & Reduce (paper §4 'Data streams and distributed data'), then fit.

    PYTHONPATH=src python examples/streaming_coreset.py
"""
import time

import jax
import numpy as np

from repro.core import DataScaler, MCTMConfig, MergeReduceCoreset, basis_features, fit_mctm, nll
from repro.data import generate


def main():
    n, chunk, k = 100_000, 4096, 256
    Y = generate("hourglass", n, seed=0)
    cfg = MCTMConfig(J=2, degree=6)
    scaler = DataScaler.fit(Y[:chunk])  # scaler from the first chunk (stream!)

    mr = MergeReduceCoreset(cfg, scaler, k=k, key=jax.random.PRNGKey(0))
    t0 = time.time()
    for i in range(0, n, chunk):
        mr.push(Y[i : i + chunk])
    res = mr.result()
    t_stream = time.time() - t0
    print(f"streamed {mr.n_seen} points → coreset of {res.size} "
          f"(Σw = {res.weights.sum():.0f}) in {t_stream:.2f}s "
          f"[{len([b for b in mr._buckets if b is not None])} live buckets]")

    fit = fit_mctm(cfg, scaler, res.Y, weights=np.asarray(res.weights, np.float32), steps=800)

    import jax.numpy as jnp

    A, Ap = basis_features(cfg, scaler, jnp.asarray(Y))
    full_fit = fit_mctm(cfg, scaler, Y, steps=800)
    r = float(nll(cfg, fit.params, A, Ap)) / float(nll(cfg, full_fit.params, A, Ap))
    print(f"stream-coreset vs full-data likelihood ratio: {r:.4f}")


if __name__ == "__main__":
    main()
