"""Streaming scenario: maintain an MCTM coreset over an insertion stream with
Merge & Reduce (paper §4 'Data streams and distributed data'), fit, and keep
a live serving slot fresh — each re-fit on the maintained coreset publishes
atomically into a ``DensityServeEngine`` while it answers queries (the
bridge to the serving layer: stream → coreset → refit → publish).

    PYTHONPATH=src python examples/streaming_coreset.py
"""
import time

import jax
import numpy as np

from repro.core import DataScaler, MCTMConfig, MergeReduceCoreset, basis_features, fit_mctm, nll
from repro.core.mctm_fit import fit_mctm_streaming
from repro.data import generate
from repro.serve import DensityServeEngine


def main():
    n, chunk, k = 100_000, 4096, 256
    Y = generate("hourglass", n, seed=0)
    cfg = MCTMConfig(J=2, degree=6)
    scaler = DataScaler.fit(Y[:chunk])  # scaler from the first chunk (stream!)

    mr = MergeReduceCoreset(cfg, scaler, k=k, key=jax.random.PRNGKey(0))
    engine = None
    refits = 0
    t0 = time.time()
    for i in range(0, n, chunk):
        mr.push(Y[i : i + chunk])
        # periodic refresh: refit on the maintained coreset and publish to
        # the serving slot without interrupting its traffic
        if (i // chunk) % 8 == 7:
            res = mr.result()
            fit = fit_mctm_streaming(
                cfg, scaler, res.Y,
                weights=np.asarray(res.weights, np.float32),
                steps=60, method="lbfgs",
            )
            if engine is None:
                engine = DensityServeEngine(cfg, fit.params, scaler, max_batch=64)
                engine.warmup()
            else:
                engine.publish(fit.params)
            # queries riding between refits all answer from one version
            probe = engine.submit_log_density(Y[:32])
            engine.run_until_drained()
            assert {r.version for r in probe} == {engine.version}
            refits += 1
    res = mr.result()
    t_stream = time.time() - t0
    print(f"streamed {mr.n_seen} points → coreset of {res.size} "
          f"(Σw = {res.weights.sum():.0f}) in {t_stream:.2f}s "
          f"[{len([b for b in mr._buckets if b is not None])} live buckets, "
          f"{refits} refits published to serving slot v{engine.version}]")

    fit = fit_mctm(cfg, scaler, res.Y, weights=np.asarray(res.weights, np.float32), steps=800)
    v_final = engine.publish(fit.params)

    import jax.numpy as jnp

    A, Ap = basis_features(cfg, scaler, jnp.asarray(Y))
    full_fit = fit_mctm(cfg, scaler, Y, steps=800)
    r = float(nll(cfg, fit.params, A, Ap)) / float(nll(cfg, full_fit.params, A, Ap))
    print(f"stream-coreset vs full-data likelihood ratio: {r:.4f} "
          f"(final fit staged as serving version {v_final})")


if __name__ == "__main__":
    main()
