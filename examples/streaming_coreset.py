"""Streaming drill: drift-triggered coreset maintenance feeding a live server.

The production stream loop (ROADMAP item 2, `docs/STREAMING.md`): a
``StreamingCoresetMaintainer`` consumes windows, a ``DriftDetector`` watches
each window's NLL under the *live serving model* (fused streamed evaluator),
and a fired alert calls ``DensityServeEngine.start_background_refit`` on the
maintained coreset — the publish lands atomically between serving ticks
while probe traffic keeps flowing.

    PYTHONPATH=src python examples/streaming_coreset.py --smoke --inject-drift

Exit status is the contract (the CI drill): 0 iff every check below holds —
  * pre-drift windows stay inside the (1±eps) band with zero alerts;
  * with ``--inject-drift``: the injected shift is detected within
    ``DETECT_BUDGET`` windows of onset, a background refit publishes, and
    the measured post-refit ε̂ re-enters the band;
  * every probe query is answered by exactly one model version (no mixed
    or dropped queries across the hot swaps).
``--no-trigger`` disables the automatic refit trigger and is the teeth mode:
the band then never recovers, the checks fail, and the script exits 1 — CI
asserts that failure the same way the analysis gate asserts its seeded
violation.
"""
import argparse
import sys
import time

import jax
import numpy as np

from repro.core import DataScaler, MCTMConfig
from repro.core.mctm_fit import fit_mctm_streaming
from repro.core.streaming import DriftDetector, StreamingCoresetMaintainer
from repro.data import generate
from repro.serve import DensityServeEngine

DETECT_BUDGET = 3      # windows from drift onset to first alert
RECOVER_BUDGET = 6     # windows from first trigger to band re-entry


def drifted(Y: np.ndarray, seed: int) -> np.ndarray:
    """The injected shift: rescale + translate the DGP draw — a mean/cov
    break the pre-drift model cannot explain."""
    rng = np.random.default_rng(seed)
    span = Y.std(axis=0)
    return (Y * 1.6 + 2.0 * span + rng.normal(scale=0.1 * span, size=Y.shape)).astype(
        np.float32
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    ap.add_argument("--inject-drift", action="store_true",
                    help="switch the stream to a shifted DGP mid-run")
    ap.add_argument("--no-trigger", action="store_true",
                    help="teeth mode: detector fires but never triggers a "
                         "refit — the drill MUST exit 1")
    args = ap.parse_args(argv)

    if args.smoke:
        window, pre_windows, drift_windows = 256, 6, 8
        k, sketch, degree, fit_steps = 96, 32, 4, 40
    else:
        window, pre_windows, drift_windows = 1024, 10, 12
        k, sketch, degree, fit_steps = 256, 64, 6, 60
    eps = 0.1

    n_pre = window * (pre_windows + 2)  # +2 windows fit the initial model
    Y_pre = np.asarray(generate("hourglass", n_pre, seed=0), np.float32)
    Y_drift = drifted(
        np.asarray(generate("hourglass", window * drift_windows, seed=1), np.float32),
        seed=2,
    )
    cfg = MCTMConfig(J=2, degree=degree)
    # scaler covers both regimes (a production scaler is set for the data
    # domain, not the current mode); the MODEL only ever sees its fit data
    scaler = DataScaler.fit(np.concatenate([Y_pre, Y_drift]))

    t0 = time.time()
    fit0 = fit_mctm_streaming(
        cfg, scaler, Y_pre[: 2 * window], key=jax.random.PRNGKey(1),
        steps=fit_steps, method="lbfgs",
    )
    engine = DensityServeEngine(cfg, fit0.params, scaler, max_batch=64)
    engine.warmup(kinds=("log_density",))
    det = DriftDetector(eps=eps, alpha=0.5, min_windows=2)
    maintainer = StreamingCoresetMaintainer(
        cfg, scaler, k, jax.random.PRNGKey(2),
        policy="sliding", window=4, sketch_size=sketch,
        serve_engine=engine, detector=det,
        auto_trigger=not args.no_trigger,
        refit_kwargs=dict(steps=fit_steps, method="lbfgs"),
    )

    mixed = dropped = probes = 0

    def probe_and_tick(rows: np.ndarray) -> None:
        """Serve probe traffic through any hot swap; count contract breaks."""
        nonlocal mixed, dropped, probes
        reqs = engine.submit_log_density(rows[:16])
        engine.run_until_drained()
        probes += len(reqs)
        versions = {r.version for r in reqs if r.done}
        dropped += sum(0 if r.done else 1 for r in reqs)
        if len(versions) > 1:
            mixed += 1

    stream = [
        Y_pre[2 * window + i * window : 2 * window + (i + 1) * window]
        for i in range(pre_windows)
    ]
    drift_onset = len(stream)
    if args.inject_drift:
        stream += [Y_drift[i * window : (i + 1) * window] for i in range(drift_windows)]

    for widx, rows in enumerate(stream):
        maintainer.push(rows)
        # a fired trigger refits in the background; wait for the publish so
        # the NEXT window re-anchors (CI determinism — production would keep
        # streaming and converge a window or two later)
        if maintainer.drift_log and maintainer.drift_log[-1]["triggered"]:
            while engine.refit_in_flight:
                time.sleep(0.05)
        probe_and_tick(rows)

    log = maintainer.drift_log
    pre_log = log[:drift_onset]
    drift_log = log[drift_onset:]
    print(f"streamed {maintainer.n_seen} rows in {len(stream)} windows "
          f"({time.time() - t0:.1f}s); serving v{engine.version}, "
          f"{det.alerts} alerts, {maintainer.triggered} triggers, "
          f"{probes} probe queries")
    for e in log:
        print(f"  w{e['window']:02d} v{e['version']} "
              f"ratio={e['ratio']:.4f} ewma={e['ewma']:.4f} "
              f"eps_hat={e['eps_hat']:.4f}"
              + (" FIRED" if e["fired"] else "")
              + (" TRIGGERED" if e["triggered"] else ""))

    failures = []
    if any(e["fired"] for e in pre_log):
        failures.append("false alarm on a pre-drift window")
    if not all(e["eps_hat"] <= eps for e in pre_log[1:]):
        failures.append("pre-drift windows left the band")
    if mixed or dropped:
        failures.append(f"serving contract broken: {mixed} mixed-version "
                        f"batches, {dropped} dropped queries")
    if args.inject_drift:
        fired = [e for e in drift_log if e["fired"]]
        if not fired:
            failures.append("injected drift was never detected")
        else:
            latency = fired[0]["window"] - drift_onset + 1
            print(f"detection latency: {latency} windows (budget {DETECT_BUDGET})")
            if latency > DETECT_BUDGET:
                failures.append(f"detection latency {latency} > {DETECT_BUDGET}")
        if engine.version < 1 or not engine.refit_log:
            failures.append("no background refit published")
        post = [e for e in drift_log if e["version"] >= 1]
        back = [e for e in post if e["eps_hat"] <= eps]
        if not post or not back or post[-1]["eps_hat"] > eps:
            failures.append("post-refit eps_hat never re-entered the band")
        elif maintainer.triggered:
            recover = back[0]["window"] - next(
                e["window"] for e in drift_log if e["triggered"]
            )
            print(f"band recovery: {recover} windows (budget {RECOVER_BUDGET})")
            if recover > RECOVER_BUDGET:
                failures.append(f"band recovery took {recover} windows "
                                f"> {RECOVER_BUDGET}")

    if failures:
        for f in failures:
            print(f"DRILL FAILED: {f}")
        return 1
    print("streaming drill OK: detected → refit → band recovered, "
          "0 dropped/mixed queries")
    return 0


if __name__ == "__main__":
    sys.exit(main())
