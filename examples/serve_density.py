"""Density serving example: fit an MCTM on a coreset, serve mixed
``log_density`` / conditional-``sample`` traffic through the
continuous-batching engine, hot-swap one live refit mid-traffic, and print
a latency summary.

    PYTHONPATH=src python examples/serve_density.py
"""
import time

import jax
import numpy as np

from repro.core import DataScaler, MCTMConfig, build_coreset
from repro.core.mctm_fit import fit_mctm_streaming
from repro.data import generate
from repro.serve import DensityServeEngine, start_background_refit


def main():
    n, k = 100_000, 1000
    Y = generate("hourglass", n, seed=0).astype(np.float32)
    cfg = MCTMConfig(J=2, degree=6)
    scaler = DataScaler.fit(Y)
    key = jax.random.PRNGKey(0)
    k_build, k_fit, k_refit = jax.random.split(key, 3)

    cs = build_coreset(cfg, scaler, Y, k, "l2-hull", key=k_build)
    fit = fit_mctm_streaming(
        cfg, scaler, Y[cs.indices],
        weights=np.asarray(cs.weights, np.float32),
        key=k_fit, steps=150, method="lbfgs",
    )
    print(f"boot fit on k={k} coreset: NLL/pt "
          f"{fit.final_nll / cs.weights.sum():.4f}")

    engine = DensityServeEngine(cfg, fit.params, scaler, max_batch=128)
    warmed = engine.warmup()
    print(f"warmed {warmed} executables over buckets {engine.buckets}")

    # mixed open-loop traffic: 3:1 log_density : conditional sample; a
    # background refit (fresh coreset, streaming L-BFGS) publishes mid-way
    rng = np.random.default_rng(1)
    reqs = []
    refit = None
    t0 = time.time()
    while len(reqs) < 4000 or (refit is not None and engine.version < 1):
        for _ in range(48):
            if rng.random() < 0.25:
                reqs += engine.submit_sample(1, y_obs=Y[rng.integers(n)],
                                             n_obs=1, seeds=[len(reqs)])
            else:
                reqs += engine.submit_log_density(Y[rng.integers(n)][None])
        if refit is None and len(reqs) >= 1500:
            refit = start_background_refit(
                engine, scaler, Y, k, key=k_refit, method="lbfgs", steps=150)
        engine.step()
    engine.run_until_drained()
    if refit is not None:
        refit.join()
    wall = time.time() - t0

    lat = np.array([r.latency_s for r in reqs]) * 1e3
    versions = sorted({r.version for r in reqs})
    print(f"served {len(reqs)} queries in {wall:.2f}s "
          f"({len(reqs) / wall:.0f} QPS)")
    print(f"latency p50 {np.percentile(lat, 50):.2f}ms  "
          f"p99 {np.percentile(lat, 99):.2f}ms")
    print(f"hot swap: versions {versions} served, "
          f"dropped={sum(1 for r in reqs if not r.done)}, "
          f"steady-state recompiles={engine.compile_count - warmed}")


if __name__ == "__main__":
    main()
