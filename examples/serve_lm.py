"""Batched serving example: prefill a batch of prompts, then decode tokens
autoregressively with the per-family cache (KV / latent / state).

    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-370m --tokens 32
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced_config
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)
    max_len = args.prompt_len + args.tokens + cfg.n_modality_positions + 1

    cache, _ = model.init_cache(args.batch, max_len)
    batch = {"tokens": prompts}
    if cfg.modality == "vision":
        batch["patch_embeds"] = rng.standard_normal(
            (args.batch, cfg.n_modality_positions, cfg.d_model)
        ).astype(np.float32) * 0.02
    if cfg.family == "encdec":
        batch = {"frames": rng.standard_normal(
            (args.batch, args.prompt_len, cfg.d_model)).astype(np.float32) * 0.02,
            "tokens": prompts[:, :4]}

    t0 = time.time()
    logits, cache = model.prefill(params, batch, cache)
    prefill_s = time.time() - t0

    decode = jax.jit(model.decode_step)
    key = jax.random.PRNGKey(1)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    generated = [np.asarray(tok)]
    t0 = time.time()
    for i in range(args.tokens - 1):
        logits, cache = decode(params, tok, cache)
        key, sub = jax.random.split(key)
        tok = jax.random.categorical(
            sub, logits[:, -1].astype(jnp.float32) / args.temperature
        ).astype(jnp.int32)[:, None]
        generated.append(np.asarray(tok))
    decode_s = time.time() - t0

    out = np.concatenate(generated, axis=1)
    print(f"arch={cfg.name} batch={args.batch}")
    print(f"prefill({args.prompt_len} tok): {prefill_s * 1e3:.1f} ms")
    print(f"decode: {args.tokens} tokens in {decode_s:.2f}s "
          f"({decode_s / max(args.tokens - 1, 1) * 1e3:.1f} ms/tok, "
          f"{args.batch * (args.tokens - 1) / decode_s:.0f} tok/s aggregate)")
    print("sample generations (token ids):")
    for b in range(min(args.batch, 2)):
        print(f"  [{b}]", out[b][:16].tolist(), "...")


if __name__ == "__main__":
    main()
