"""Quickstart: the paper in 40 lines.

Generate a complex 2-d distribution, fit the full-data MCTM, build an
ℓ2-hull coreset of 50 points, refit, and compare — the paper's headline:
the coreset fit matches the full fit at a fraction of the cost.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import numpy as np

from repro.core import (
    DataScaler,
    MCTMConfig,
    build_coreset,
    fit_mctm,
    log_density,
)
from repro.data import generate


def main():
    Y = generate("normal_mixture", 20_000, seed=0)
    cfg = MCTMConfig(J=2, degree=6)
    scaler = DataScaler.fit(Y)

    t0 = time.time()
    full = fit_mctm(cfg, scaler, Y, steps=800)
    t_full = time.time() - t0
    print(f"full fit:    n={len(Y):6d}  NLL/n={full.final_nll / len(Y):.4f}  ({t_full:.1f}s)")

    cs = build_coreset(cfg, scaler, Y, k=50, method="l2-hull", key=jax.random.PRNGKey(0))
    t0 = time.time()
    small = fit_mctm(
        cfg, scaler, Y[cs.indices], weights=np.asarray(cs.weights, np.float32), steps=800
    )
    t_cs = time.time() - t0

    # evaluate both on the FULL data
    import jax.numpy as jnp
    from repro.core import basis_features, nll

    A, Ap = basis_features(cfg, scaler, jnp.asarray(Y))
    nll_full = float(nll(cfg, full.params, A, Ap))
    nll_cs = float(nll(cfg, small.params, A, Ap))
    print(f"coreset fit: k={cs.size:6d}  NLL/n={nll_cs / len(Y):.4f}  ({t_cs:.1f}s)")
    print(f"likelihood ratio = {nll_cs / nll_full:.4f}  (1.0 = perfect)")
    print(f"fit speedup       = {t_full / t_cs:.1f}x  (+{cs.seconds:.2f}s scoring)")

    # density slice sanity check
    pts = jnp.asarray([[0.0, 0.0], [3.0, -2.0], [10.0, 10.0]])
    print("log-density at [mode1, mode2, far]:",
          np.round(np.asarray(log_density(cfg, small.params, scaler, pts)), 2))


if __name__ == "__main__":
    main()
