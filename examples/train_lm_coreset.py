"""End-to-end driver: train a (reduced) LM for a few hundred steps with the
paper's coreset data-reduction as a first-class pipeline stage, and compare
against uniform selection at equal budget.

    PYTHONPATH=src python examples/train_lm_coreset.py [--steps 200]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_reduced_config
from repro.data.pipeline import CoresetSelector, subset_loader
from repro.data.synthetic_lm import TokenStreamConfig, sample_batch
from repro.models import build_model
from repro.optim import adamw, chain, clip_by_global_norm, cosine_warmup
from repro.train import init_train_state, make_train_step


def train(model, params, batch_fn, steps, lr=3e-3):
    opt = chain(clip_by_global_norm(1.0), adamw(cosine_warmup(lr, 20, steps)))
    state = init_train_state(params, opt)
    step_fn = jax.jit(make_train_step(model, opt))
    losses = []
    for i in range(steps):
        state, m = step_fn(state, batch_fn(i))
        losses.append(float(m["loss"]))
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="tinyllama-1.1b")
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))

    # a 2048-example corpus; budget: train on a 256-example subset
    stream = TokenStreamConfig(vocab_size=cfg.vocab_size, seq_len=32)
    corpus = [sample_batch(stream, 128, s) for s in range(16)]
    data = {k: np.concatenate([c[k] for c in corpus]) for k in ("tokens", "labels")}

    emb = np.asarray(params["emb"]["embed"], np.float32)
    featurize = lambda toks: emb[toks].mean(axis=1)

    results = {}
    for method in ("l2-hull", "uniform"):
        sel = CoresetSelector(featurize=featurize, method=method)
        t0 = time.time()
        sub = sel.select(data["tokens"], k=256, key=jax.random.PRNGKey(1))
        sel_s = time.time() - t0
        fn = subset_loader(data, sub, batch=16)
        losses = train(model, params, fn, args.steps)
        results[method] = losses
        print(
            f"{method:8s}: select {sel_s:.2f}s | loss {losses[0]:.3f} → "
            f"{np.mean(losses[-10:]):.3f} (last-10 mean)"
        )

    gap = np.mean(results["uniform"][-10:]) - np.mean(results["l2-hull"][-10:])
    print(f"l2-hull final-loss advantage over uniform: {gap:+.4f}")


if __name__ == "__main__":
    main()
