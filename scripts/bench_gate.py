"""Bench-regression gate: diff freshly generated BENCH_*.json records against
checked-in baselines and FAIL (exit 1) on regression, instead of only
uploading artifacts.

Convention (recorded in ROADMAP.md): CI smoke runs write their records to
``results/bench/BENCH_*_smoke*.json``; the committed reference records for
the same smoke configuration live in ``benchmarks/baselines/``. The gate
compares generated vs baseline per metric class:

* **time-ratio metrics** (speedups — dimensionless ratios of two timings on
  the SAME machine, so they transfer across runners): a regression of more
  than ``--time-ratio`` (default 1.5×) fails, i.e. generated must be
  ≥ baseline / 1.5. Raw wall-clock seconds are never gated — they don't
  transfer across runners.
* **exact-tolerance metrics** (ε̂, score diffs, sketch errors — quality
  numbers that only move with code/version changes): generated must stay
  within a small multiplicative + absolute envelope of the baseline
  (``value ≤ baseline·rel + abs``), so a quality regression can't hide
  behind runner noise.
* **invariants** (booleans like ``all_within_band``/``hull_points_equal``
  and config fields like n/degree/chunk): must hold exactly; a config
  mismatch means the comparison is meaningless and also fails.
* **floor metrics** (headline claims like "one-pass is strictly faster than
  two-pass"): the generated value must be ≥ an ABSOLUTE floor, independent
  of the baseline — runner noise may move the margin but may never flip the
  claim itself.

Usage::

    python scripts/bench_gate.py                         # gate all defaults
    python scripts/bench_gate.py --generated results/bench/BENCH_scoring_smoke.json \
        --baseline benchmarks/baselines/BENCH_scoring_smoke.json

Missing generated files fail (the bench didn't run); missing baselines fail
(the gate is wired but unbaselined) unless ``--allow-missing-baseline``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
from typing import Any

REPO_ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))
GENERATED_DIR = os.path.join("results", "bench")
BASELINE_DIR = os.path.join("benchmarks", "baselines")


@dataclasses.dataclass(frozen=True)
class Rule:
    """One gated metric. ``path`` is a dotted path into the record;
    ``[]`` segments map over list elements (e.g. ``per_k.[].eps_hat``)."""

    path: str
    kind: str            # "time_ratio" | "exact" | "invariant" | "floor"
    rel: float = 1.5     # exact: multiplicative envelope
    abs: float = 0.0     # exact: additive envelope
    ratio: float | None = None  # time_ratio: per-rule override of --time-ratio
    floor: float = 0.0   # floor: absolute minimum for the generated value


# Per-file rule sets, keyed by the basename prefix of the generated record.
RULES: dict[str, list[Rule]] = {
    "BENCH_scoring": [
        Rule("n", "invariant"),
        Rule("degree", "invariant"),
        Rule("chunk_size", "invariant"),
        Rule("speedup", "time_ratio"),
        Rule("max_abs_score_diff", "exact", rel=4.0, abs=1e-6),
        Rule("one_pass_vs_two_pass.speedup", "time_ratio"),
        # the headline claim of the fused sweep kernel: one-pass STRICTLY
        # dominates two-pass — the 0.95x regression can never silently return
        Rule("one_pass_vs_two_pass.speedup", "floor", floor=1.0),
        Rule("one_pass_vs_two_pass.fused_vs_unfused.measured_speedup",
             "time_ratio"),
        Rule("one_pass_vs_two_pass.fused_vs_unfused.measured_speedup",
             "floor", floor=1.0),
        Rule("one_pass_vs_two_pass.one_pass_rows_streamed", "invariant"),
        Rule("one_pass_vs_two_pass.one_pass_featurize_calls", "invariant"),
        Rule("one_pass_vs_two_pass.median_rel_score_err", "exact", rel=2.0, abs=0.01),
        Rule("one_pass_vs_two_pass.max_rel_score_err", "exact", rel=2.0, abs=0.05),
    ],
    "BENCH_dist_scoring": [
        Rule("n", "invariant"),
        Rule("degree", "invariant"),
        Rule("devices", "invariant"),
        Rule("hull_points_equal", "invariant"),
        Rule("speedup", "time_ratio"),
        Rule("max_abs_score_diff", "exact", rel=4.0, abs=1e-7),
    ],
    "BENCH_mctm_fit": [
        Rule("n", "invariant"),
        Rule("degree", "invariant"),
        Rule("steps", "invariant"),
        Rule("fit_method", "invariant"),
        Rule("ref_method", "invariant"),
        Rule("all_within_band", "invariant"),
        Rule("full_nll_per_point", "exact", rel=1.0, abs=0.01),
        Rule("per_k.[].within_band", "invariant"),
        Rule("per_k.[].eps_hat", "exact", rel=1.5, abs=0.01),
        # numerator (long ref fit) and denominator (seconds-long coreset
        # build+fit) are timed at different points of the run, so transient
        # runner load skews this ratio far more than the back-to-back
        # scoring speedups — wider envelope, still catches order-of-magnitude
        # regressions
        Rule("per_k.[].speedup_vs_full_fit", "time_ratio", ratio=3.0),
    ],
    "BENCH_serve": [
        Rule("n", "invariant"),
        Rule("k", "invariant"),
        Rule("degree", "invariant"),
        Rule("max_batch", "invariant"),
        # the serving contracts are hard zeros, not envelopes: a single
        # steady-state retrace, dropped query, or mixed-params answer is a
        # broken scheduler/hot-swap protocol, whatever the runner
        Rule("steady_state_recompiles", "invariant"),
        Rule("hot_swap.dropped", "invariant"),
        Rule("hot_swap.mixed_params_queries", "invariant"),
        Rule("zero_dropped_or_mixed", "invariant"),
        Rule("coalesced_vs_unbatched.speedup", "time_ratio"),
        # the headline throughput claim: request coalescing beats
        # per-request dispatch ≥ 5x at smoke load (absolute floor — runner
        # noise may move the margin, never flip the claim)
        Rule("coalesced_vs_unbatched.speedup", "floor", floor=5.0),
        # open-loop tails are noisy on shared runners: gate the p99 as an
        # exact ceiling with generous slack rather than a tight envelope
        Rule("load_sweep.[].p99_ms", "exact", rel=5.0, abs=50.0),
    ],
    "BENCH_ft": [
        Rule("n_score", "invariant"),
        Rule("score_chunks", "invariant"),
        Rule("n_fit", "invariant"),
        Rule("fit_steps", "invariant"),
        Rule("resume_bit_identical", "invariant"),
        Rule("recovered", "invariant"),
        # overhead ratios are smaller-better (1.0 = free), so they gate as
        # "exact" ceilings, never "time_ratio" floors; both compare two
        # timings from the same run, but the ckpt sweep adds host I/O and
        # the recovery fit replays from the last checkpoint, so give them
        # generous multiplicative + absolute slack for runner noise
        Rule("ckpt_overhead_ratio", "exact", rel=1.5, abs=0.5),
        Rule("recovery_overhead_ratio", "exact", rel=1.5, abs=0.5),
    ],
    "BENCH_stream": [
        Rule("n", "invariant"),
        Rule("window", "invariant"),
        Rule("n_windows", "invariant"),
        Rule("k", "invariant"),
        Rule("degree", "invariant"),
        Rule("sketch_size", "invariant"),
        # the streaming policies are exact contracts, not envelopes: eviction
        # order, the geometric decay sum, result() idempotence and crash/
        # resume bit-identity either hold or the maintainer is broken
        Rule("policy_checks.sliding_evicts_expired", "invariant"),
        Rule("policy_checks.decayed_weight_matches_closed_form", "invariant"),
        Rule("policy_checks.result_idempotent", "invariant"),
        Rule("stream_interrupts", "invariant"),
        Rule("resume_bit_identical", "invariant"),
        # maintenance must beat a full-prefix rebuild outright (the reason
        # the streaming layer exists), with the usual runner-noise envelope
        # on top of the absolute claim
        Rule("maintain_vs_rebuild.speedup", "time_ratio"),
        Rule("maintain_vs_rebuild.speedup", "floor", floor=1.0),
        # drift drill: the detector must fire within the committed latency
        # (ceiling = baseline latency + 2 windows of slack, the drill's
        # DETECT_BUDGET), the post-refit band must be re-entered with margin,
        # and the serving contract is a hard zero across the hot swaps
        Rule("drift.detected", "invariant"),
        Rule("drift.detection_latency_windows", "exact", rel=1.0, abs=2.0),
        Rule("drift.triggers", "floor", floor=1.0),
        Rule("drift.post_refit_eps_hat", "exact", rel=1.5, abs=0.05),
        Rule("drift.post_refit_in_band", "invariant"),
        Rule("drift.mixed_version_batches", "invariant"),
        Rule("drift.dropped_queries", "invariant"),
    ],
}

# Default gate targets: (generated relpath, baseline relpath).
DEFAULT_PAIRS = [
    ("BENCH_scoring_smoke.json", "BENCH_scoring_smoke.json"),
    ("BENCH_dist_scoring_smoke.json", "BENCH_dist_scoring_smoke.json"),
    ("BENCH_mctm_fit_smoke.json", "BENCH_mctm_fit_smoke.json"),
    ("BENCH_mctm_fit_smoke_lbfgs.json", "BENCH_mctm_fit_smoke_lbfgs.json"),
    ("BENCH_mctm_fit_smoke_minibatch.json", "BENCH_mctm_fit_smoke_minibatch.json"),
    ("BENCH_ft_smoke.json", "BENCH_ft_smoke.json"),
    ("BENCH_serve_smoke.json", "BENCH_serve_smoke.json"),
    ("BENCH_stream_smoke.json", "BENCH_stream_smoke.json"),
]


def _lookup(record: Any, path: str) -> list[tuple[str, Any]]:
    """Resolve a dotted path; ``[]`` fans out over list elements. Returns
    (concrete_path, value) pairs — missing keys resolve to a single
    ``(path, KeyError)`` marker the caller reports."""
    out = [("", record)]
    for seg in path.split("."):
        nxt = []
        for prefix, val in out:
            if seg == "[]":
                if not isinstance(val, list):
                    return [(path, KeyError(f"{prefix or '<root>'} is not a list"))]
                nxt.extend((f"{prefix}[{i}]", v) for i, v in enumerate(val))
            else:
                if not isinstance(val, dict) or seg not in val:
                    return [(path, KeyError(f"missing key {seg!r} under "
                                            f"{prefix or '<root>'}"))]
                nxt.append((f"{prefix}.{seg}".lstrip("."), val[seg]))
        out = nxt
    return out


def check_rule(rule: Rule, generated: dict, baseline: dict,
               time_ratio: float) -> list[str]:
    """Return failure messages for one rule (empty = pass)."""
    gen = _lookup(generated, rule.path)
    base = _lookup(baseline, rule.path)
    if any(isinstance(v, KeyError) for _, v in gen):
        return [f"{rule.path}: {gen[0][1]} in generated record"]
    if any(isinstance(v, KeyError) for _, v in base):
        return [f"{rule.path}: {base[0][1]} in baseline record"]
    if len(gen) != len(base):
        return [f"{rule.path}: generated has {len(gen)} entries, "
                f"baseline {len(base)} — records not comparable"]
    fails = []
    for (where, g), (_, b) in zip(gen, base):
        if rule.kind == "invariant":
            if g != b:
                fails.append(f"{where}: invariant {g!r} != baseline {b!r}")
        elif rule.kind == "time_ratio":
            ratio = rule.ratio if rule.ratio is not None else time_ratio
            floor = float(b) / ratio
            if float(g) < floor:
                fails.append(
                    f"{where}: {float(g):.4g} regressed more than "
                    f"{ratio}x vs baseline {float(b):.4g} "
                    f"(floor {floor:.4g})"
                )
        elif rule.kind == "floor":
            if float(g) < rule.floor:
                fails.append(
                    f"{where}: {float(g):.4g} is below the absolute floor "
                    f"{rule.floor:.4g} — the gated claim no longer holds"
                )
        elif rule.kind == "exact":
            ceiling = float(b) * rule.rel + rule.abs
            if float(g) > ceiling:
                fails.append(
                    f"{where}: {float(g):.6g} exceeds tolerance ceiling "
                    f"{ceiling:.6g} (baseline {float(b):.6g} × {rule.rel} "
                    f"+ {rule.abs})"
                )
        else:  # pragma: no cover - rule table is static
            raise ValueError(rule.kind)
    return fails


def rules_for(path: str) -> list[Rule] | None:
    name = os.path.basename(path)
    for prefix in sorted(RULES, key=len, reverse=True):
        if name.startswith(prefix):
            return RULES[prefix]
    return None


def gate_pair(gen_path: str, base_path: str, *, time_ratio: float,
              allow_missing_baseline: bool = False) -> list[str]:
    """Gate one generated/baseline file pair; returns failure messages."""
    rules = rules_for(gen_path)
    if rules is None:
        return [f"{gen_path}: no rule set matches this filename"]
    if not os.path.exists(gen_path):
        return [f"{gen_path}: generated record missing (bench did not run?)"]
    if not os.path.exists(base_path):
        if allow_missing_baseline:
            print(f"[bench_gate] SKIP {gen_path} (no baseline at {base_path})")
            return []
        return [f"{base_path}: baseline missing — generate it and commit "
                f"(see ROADMAP bench-gate convention)"]
    with open(gen_path) as f:
        generated = json.load(f)
    with open(base_path) as f:
        baseline = json.load(f)
    fails = []
    for rule in rules:
        fails.extend(
            f"{os.path.basename(gen_path)} :: {msg}"
            for msg in check_rule(rule, generated, baseline, time_ratio)
        )
    return fails


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--generated", default=None,
                    help="one generated record (requires --baseline)")
    ap.add_argument("--baseline", default=None)
    ap.add_argument("--generated-dir", default=os.path.join(REPO_ROOT, GENERATED_DIR))
    ap.add_argument("--baseline-dir", default=os.path.join(REPO_ROOT, BASELINE_DIR))
    ap.add_argument("--time-ratio", type=float, default=1.5,
                    help="max tolerated wall-clock-ratio regression")
    ap.add_argument("--allow-missing-baseline", action="store_true")
    args = ap.parse_args(argv)

    if (args.generated is None) != (args.baseline is None):
        ap.error("--generated and --baseline must be passed together")
    if args.generated:
        pairs = [(args.generated, args.baseline)]
    else:
        pairs = [
            (os.path.join(args.generated_dir, g), os.path.join(args.baseline_dir, b))
            for g, b in DEFAULT_PAIRS
        ]

    failures = []
    for gen_path, base_path in pairs:
        fails = gate_pair(
            gen_path, base_path, time_ratio=args.time_ratio,
            allow_missing_baseline=args.allow_missing_baseline,
        )
        if fails:
            failures.extend(fails)
        elif os.path.exists(gen_path):
            print(f"[bench_gate] PASS {os.path.relpath(gen_path, REPO_ROOT)}")
    if failures:
        print(f"[bench_gate] {len(failures)} regression(s):", file=sys.stderr)
        for msg in failures:
            print(f"  FAIL {msg}", file=sys.stderr)
        return 1
    print("[bench_gate] all gates passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
