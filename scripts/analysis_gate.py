"""Compile-time invariant gate: audit every registered jitted hot path and
FAIL (exit 1) on invariant violations, AST-lint findings, or metric drift
against the committed baseline.

What runs (all static — lower/compile on CPU, never execute):

* ``repro.analysis`` audits every registered program (collective census,
  materialization bound, dtype promotion, buffer donation, host callbacks)
  — see ``docs/INVARIANTS.md`` for the invariant catalogue.
* ``repro.analysis.ast_lints`` lints ``src/repro`` for PRNG key reuse,
  ``np.`` math on traced values, and mutable default arguments.
* The measured per-program metrics are written to
  ``results/analysis/ANALYSIS_report.json`` and diffed EXACTLY against the
  committed baseline ``benchmarks/baselines/ANALYSIS_budgets.json``
  (bench_gate-style). Any drift — even a "harmless" new collective or a new
  weak-type constant — fails until the baseline is regenerated.

Convention (recorded in ROADMAP.md and benchmarks/baselines/README.md): a PR
that intentionally changes a lowering regenerates the baseline IN THE SAME
PR with ``--write-baseline`` and the diff gets reviewed like any other code.

Usage::

    python scripts/analysis_gate.py                      # full gate
    python scripts/analysis_gate.py --programs streamed_nll_sharded
    python scripts/analysis_gate.py --write-baseline     # refresh baseline
    python scripts/analysis_gate.py --seed-violation extra_psum  # must exit 1
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# The sharded programs need 8 fake devices; must be set before jax imports.
_DEVICES = 8
flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in flags:
    os.environ["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={_DEVICES}".strip()
    )
os.environ.setdefault("JAX_PLATFORMS", "cpu")

REPO_ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(REPO_ROOT, "src"))

BASELINE = os.path.join("benchmarks", "baselines", "ANALYSIS_budgets.json")
REPORT_DIR = os.path.join("results", "analysis")
LINT_ROOT = os.path.join("src", "repro")


def run_audits(names: list[str] | None) -> list[dict]:
    from repro.analysis import all_programs, audit_program, get_program

    specs = [get_program(n) for n in names] if names else all_programs()
    reports = []
    for spec in specs:
        print(f"auditing {spec.name} ...", flush=True)
        reports.append(audit_program(spec))
    return reports


def diff_baseline(reports: list[dict], baseline_path: str) -> list[str]:
    """Exact metric diff, bench_gate-style: any drift is a failure."""
    if not os.path.exists(baseline_path):
        return [
            f"missing baseline {baseline_path} — run "
            f"`python scripts/analysis_gate.py --write-baseline` and commit it"
        ]
    with open(baseline_path) as f:
        baseline = json.load(f)
    base_programs: dict = baseline.get("programs", {})
    problems = []
    seen = set()
    for rep in reports:
        name = rep["name"]
        seen.add(name)
        if name not in base_programs:
            problems.append(
                f"{name}: not in baseline — regenerate with --write-baseline"
            )
            continue
        want = base_programs[name]
        got = rep["metrics"]
        keys = sorted(set(want) | set(got))
        for k in keys:
            if want.get(k) != got.get(k):
                problems.append(
                    f"{name}: metric {k} drifted: baseline {want.get(k)!r} "
                    f"→ measured {got.get(k)!r}"
                )
    for name in sorted(set(base_programs) - seen):
        problems.append(
            f"{name}: in baseline but not audited — deleted program? "
            f"regenerate with --write-baseline"
        )
    return problems


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--programs", default=None,
                    help="comma-separated subset of program names "
                         "(subset runs skip the baseline diff)")
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--report-dir", default=REPORT_DIR)
    ap.add_argument("--write-baseline", action="store_true",
                    help="write the measured metrics as the new baseline")
    ap.add_argument("--no-lint", action="store_true",
                    help="skip the AST lint sweep")
    ap.add_argument("--seed-violation", default=None,
                    help="audit a deliberately broken program from "
                         "repro.analysis.violations; the gate MUST exit 1")
    args = ap.parse_args(argv)
    os.chdir(REPO_ROOT)

    if args.seed_violation is not None:
        from repro.analysis import audit_program
        from repro.analysis.violations import VIOLATIONS

        if args.seed_violation not in VIOLATIONS:
            print(f"unknown violation {args.seed_violation!r} "
                  f"(known: {', '.join(sorted(VIOLATIONS))})")
            return 2
        rep = audit_program(VIOLATIONS[args.seed_violation])
        for f in rep["failures"]:
            print(f"  ! {rep['name']}: {f}")
        if rep["ok"]:
            print(f"VIOLATION MISSED: {args.seed_violation} audited clean — "
                  f"the gate has lost its teeth")
            return 0  # distinguishable from detection in tests: 0 == missed
        print(f"violation {args.seed_violation!r} detected; failing as it should")
        return 1

    names = args.programs.split(",") if args.programs else None
    reports = run_audits(names)

    failures: list[str] = []
    for rep in reports:
        for f in rep["failures"]:
            failures.append(f"{rep['name']}: {f}")

    lint_findings = []
    if not args.no_lint:
        from repro.analysis.ast_lints import lint_paths

        lint_findings = lint_paths(LINT_ROOT)
        for f in lint_findings:
            failures.append(f"lint: {f}")

    import jax

    report = {
        "jax": jax.__version__,
        "device_count": jax.device_count(),
        "programs": {r["name"]: r["metrics"] for r in reports},
        "failures": failures,
        "lint_findings": [str(f) for f in lint_findings],
    }
    os.makedirs(args.report_dir, exist_ok=True)
    report_path = os.path.join(args.report_dir, "ANALYSIS_report.json")
    with open(report_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"wrote {report_path} ({len(reports)} programs audited)")

    if args.write_baseline:
        if names is not None:
            print("refusing to --write-baseline from a --programs subset")
            return 2
        with open(args.baseline, "w") as f:
            json.dump({"programs": report["programs"]}, f, indent=2,
                      sort_keys=True)
            f.write("\n")
        print(f"wrote baseline {args.baseline}")

    if names is None:
        failures.extend(diff_baseline(reports, args.baseline))

    if failures:
        print(f"\nANALYSIS GATE: FAIL ({len(failures)} problem(s))")
        for f in failures:
            print(f"  ! {f}")
        return 1
    print(f"\nANALYSIS GATE: OK — {len(reports)} programs within budget, "
          f"{0 if args.no_lint else len(lint_findings)} lint findings")
    return 0


if __name__ == "__main__":
    sys.exit(main())
