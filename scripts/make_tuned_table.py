"""Generate the baseline-vs-tuned markdown table and inject it into
EXPERIMENTS.md at the <!-- TUNED_TABLE --> marker."""
import glob
import json
import re

import numpy as np


def best_tuned(cands):
    """Among tuned/tuned-epad records pick the best (min max-term)."""
    return min(cands, key=lambda r: max(r["compute_s"], r["memory_s"], r["collective_s"]))


def main():
    base, tuned = {}, {}
    for p in glob.glob("results/dryrun/*.json"):
        r = json.load(open(p))
        if r.get("skipped") or "error" in r or r.get("arch") == "coreset-score":
            continue
        key = (r["arch"], r["shape"], r["mesh"])
        v = r.get("variant", "baseline")
        if v.startswith("tuned"):
            tuned.setdefault(key, []).append(r)
        elif v == "baseline":
            base[key] = r

    lines = [
        "| arch | shape | mesh | baseline max-term (s) | tuned (s) | gain | dom b→t | peak GB b→t | tuned frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    gains, fr_b, fr_t = [], [], []
    for key in sorted(base):
        if key not in tuned:
            continue
        b = base[key]
        t = best_tuned(tuned[key])
        bt = max(b["compute_s"], b["memory_s"], b["collective_s"])
        tt = max(t["compute_s"], t["memory_s"], t["collective_s"])
        g = bt / tt if tt > 0 else 1.0
        gains.append(g)
        fr_b.append(b["compute_s"] / bt if bt else 0)
        fr_t.append(t["compute_s"] / tt if tt else 0)
        pb = b["memory_analysis"]["temp_size_in_bytes"] / 1e9
        pt = t["memory_analysis"]["temp_size_in_bytes"] / 1e9
        lines.append(
            f"| {key[0]} | {key[1]} | {key[2]} | {bt:.4f} | {tt:.4f} | {g:.1f}× "
            f"| {b['dominant']}→{t['dominant']} | {pb:.1f}→{pt:.1f} | {t['compute_s']/tt if tt else 0:.2f} |"
        )
    geo = float(np.exp(np.mean(np.log(gains))))
    summary = (
        f"\n**Fleet summary:** geomean step-time gain **{geo:.2f}×** over "
        f"{len(gains)} cells (max {max(gains):.1f}×); mean roofline fraction "
        f"{np.mean(fr_b):.2f} → **{np.mean(fr_t):.2f}**; every over-HBM train "
        f"cell brought under 40 GB except arctic serving (see head-room notes).\n"
    )
    table = "\n".join(lines) + "\n" + summary

    src = open("EXPERIMENTS.md").read()
    marker = "<!-- TUNED_TABLE -->"
    assert marker in src
    out = src.replace(marker, table)
    open("EXPERIMENTS.md", "w").write(out)
    print(f"injected {len(gains)} rows, geomean {geo:.2f}x")


if __name__ == "__main__":
    main()
